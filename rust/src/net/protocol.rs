//! Request/response payload encoding for the shard-worker protocol.
//!
//! Payloads ride inside [`super::frame`] frames and are encoded with the
//! same little-endian [`crate::util::codec`] vocabulary as the checkpoint
//! layer, so every scalar — in particular every `f64` — crosses the wire
//! bit-exactly. That is a correctness requirement, not a nicety: the
//! distributed backend's outputs must be byte-identical to
//! [`crate::runtime::CpuBackend`]'s (DESIGN.md §Distribution).
//!
//! Request layout: `u64 req_id, u8 op, <op body>`. Response layout:
//! `u64 req_id, u8 status` with `status = 0` followed by the op-specific
//! body, or `status = 1` followed by a length-prefixed UTF-8 error string.
//! The echoed `req_id` lets the coordinator reject stale responses after a
//! reconnect (requests are idempotent, so a retried request may legally be
//! answered twice; only the reply matching the live id is consumed).
//!
//! Index sets are *shard-local* `u32`s: the coordinator subtracts the
//! shard's `start` before encoding, so a worker never needs the global
//! index space and an out-of-range index is always a protocol error.

use crate::models::ModelKind;
use crate::util::codec::{ByteReader, ByteWriter};

/// Per-connection handshake carrying the model specification (op body:
/// [`ModelSpec`]). Must be the first request on every connection.
pub const OP_HELLO: u8 = 1;
/// Re-anchor the worker's bound at a new θ (op body: `f64_slice` anchor).
pub const OP_SET_ANCHOR: u8 = 2;
/// Per-point log L_n (op body: θ + shard-local indices).
pub const OP_EVAL_LIK: u8 = 3;
/// Per-point (log L_n, log B_n).
pub const OP_EVAL_BOTH: u8 = 4;
/// log L_n plus per-datum gradient product rows.
pub const OP_EVAL_LIK_GRAD_ROWS: u8 = 5;
/// (log L_n, log B_n) plus per-datum pseudo-likelihood gradient rows.
pub const OP_EVAL_PSEUDO_GRAD_ROWS: u8 = 6;
/// Liveness probe (empty body).
pub const OP_PING: u8 = 7;
/// Ask the worker process/thread to exit after replying (empty body).
pub const OP_SHUTDOWN: u8 = 8;

/// Everything a worker needs to rebuild its shard's slice of the model,
/// bit-identically to the coordinator slicing its own full model: the
/// model family, global shape, the scalar bound hyper-parameters, and the
/// current anchor θ (if the bounds have been MAP-tuned). Anchor tuning is
/// per-datum (DESIGN.md §Distribution), so a worker retuning only its own
/// rows at the same θ reproduces the full model's per-datum anchors
/// exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// model family
    pub kind: ModelKind,
    /// global dataset size N (workers own a contiguous slice of it)
    pub n: usize,
    /// feature dimension D
    pub d: usize,
    /// softmax class count K (1 for the other families)
    pub k: usize,
    /// logistic untuned JJ anchor ξ (ignored by other families)
    pub xi_const: f64,
    /// robust-t degrees of freedom ν (ignored by other families)
    pub nu: f64,
    /// robust-t scale σ (ignored by other families)
    pub sigma: f64,
    /// bound anchor θ, present once the bounds have been tuned
    pub anchor: Option<Vec<f64>>,
}

fn kind_to_u8(kind: ModelKind) -> u8 {
    match kind {
        ModelKind::Logistic => 0,
        ModelKind::Softmax => 1,
        ModelKind::Robust => 2,
    }
}

fn kind_from_u8(v: u8) -> Result<ModelKind, String> {
    match v {
        0 => Ok(ModelKind::Logistic),
        1 => Ok(ModelKind::Softmax),
        2 => Ok(ModelKind::Robust),
        _ => Err(format!("unknown model-kind byte {v}")),
    }
}

impl ModelSpec {
    /// Append the wire encoding to `w`.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.u8(kind_to_u8(self.kind));
        w.usize(self.n);
        w.usize(self.d);
        w.usize(self.k);
        w.f64(self.xi_const);
        w.f64(self.nu);
        w.f64(self.sigma);
        w.bool(self.anchor.is_some());
        if let Some(a) = &self.anchor {
            w.f64_slice(a);
        }
    }

    /// Decode the [`Self::encode`] layout.
    pub fn decode(r: &mut ByteReader) -> Result<Self, String> {
        let kind = kind_from_u8(r.u8()?)?;
        let n = r.usize()?;
        let d = r.usize()?;
        let k = r.usize()?;
        let xi_const = r.f64()?;
        let nu = r.f64()?;
        let sigma = r.f64()?;
        let anchor = if r.bool()? { Some(r.f64_vec()?) } else { None };
        Ok(ModelSpec { kind, n, d, k, xi_const, nu, sigma, anchor })
    }
}

/// A decoded request, as seen by the worker serve loop. The coordinator
/// side encodes straight from borrowed slices (`encode_eval` and friends)
/// to avoid copying θ and the index set an extra time.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// per-connection handshake
    Hello(ModelSpec),
    /// re-anchor the bounds at this θ
    SetAnchor(Vec<f64>),
    /// per-point log L_n at θ over shard-local indices
    EvalLik {
        /// flattened parameter vector
        theta: Vec<f64>,
        /// shard-local datum indices
        idx: Vec<u32>,
    },
    /// per-point (log L_n, log B_n)
    EvalBoth {
        /// flattened parameter vector
        theta: Vec<f64>,
        /// shard-local datum indices
        idx: Vec<u32>,
    },
    /// log L_n plus gradient product rows
    EvalLikGradRows {
        /// flattened parameter vector
        theta: Vec<f64>,
        /// shard-local datum indices
        idx: Vec<u32>,
    },
    /// (log L_n, log B_n) plus pseudo-likelihood gradient rows
    EvalPseudoGradRows {
        /// flattened parameter vector
        theta: Vec<f64>,
        /// shard-local datum indices
        idx: Vec<u32>,
    },
    /// liveness probe
    Ping,
    /// exit after replying
    Shutdown,
}

fn header(req_id: u64, op: u8) -> ByteWriter {
    let mut w = ByteWriter::new();
    w.u64(req_id);
    w.u8(op);
    w
}

/// Encode a Hello request.
pub fn encode_hello(req_id: u64, spec: &ModelSpec) -> Vec<u8> {
    let mut w = header(req_id, OP_HELLO);
    spec.encode(&mut w);
    w.into_bytes()
}

/// Encode a SetAnchor request.
pub fn encode_set_anchor(req_id: u64, anchor: &[f64]) -> Vec<u8> {
    let mut w = header(req_id, OP_SET_ANCHOR);
    w.f64_slice(anchor);
    w.into_bytes()
}

/// Encode one of the four eval requests (`op` must be an `OP_EVAL_*`
/// constant); `idx` holds shard-local indices.
pub fn encode_eval(req_id: u64, op: u8, theta: &[f64], idx: &[u32]) -> Vec<u8> {
    debug_assert!((OP_EVAL_LIK..=OP_EVAL_PSEUDO_GRAD_ROWS).contains(&op));
    let mut w = header(req_id, op);
    w.f64_slice(theta);
    w.u32_slice(idx);
    w.into_bytes()
}

/// Encode a bodyless request (`OP_PING` / `OP_SHUTDOWN`).
pub fn encode_bodyless(req_id: u64, op: u8) -> Vec<u8> {
    header(req_id, op).into_bytes()
}

/// Decode any request payload into `(req_id, Request)`.
pub fn decode_request(payload: &[u8]) -> Result<(u64, Request), String> {
    let mut r = ByteReader::new(payload);
    let req_id = r.u64()?;
    let op = r.u8()?;
    let req = match op {
        OP_HELLO => Request::Hello(ModelSpec::decode(&mut r)?),
        OP_SET_ANCHOR => Request::SetAnchor(r.f64_vec()?),
        OP_EVAL_LIK | OP_EVAL_BOTH | OP_EVAL_LIK_GRAD_ROWS | OP_EVAL_PSEUDO_GRAD_ROWS => {
            let theta = r.f64_vec()?;
            let idx = r.u32_vec()?;
            match op {
                OP_EVAL_LIK => Request::EvalLik { theta, idx },
                OP_EVAL_BOTH => Request::EvalBoth { theta, idx },
                OP_EVAL_LIK_GRAD_ROWS => Request::EvalLikGradRows { theta, idx },
                _ => Request::EvalPseudoGradRows { theta, idx },
            }
        }
        OP_PING => Request::Ping,
        OP_SHUTDOWN => Request::Shutdown,
        _ => return Err(format!("unknown request op {op}")),
    };
    r.finish()?;
    Ok((req_id, req))
}

/// Start an ok-response payload: header written, op body appended by the
/// caller before `into_bytes()`.
pub fn ok_response(req_id: u64) -> ByteWriter {
    let mut w = ByteWriter::new();
    w.u64(req_id);
    w.u8(0);
    w
}

/// Encode an error response carrying a human-readable message.
pub fn err_response(req_id: u64, msg: &str) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u64(req_id);
    w.u8(1);
    w.bytes(msg.as_bytes());
    w.into_bytes()
}

/// Check a response payload against the expected request id and unwrap its
/// status byte. Returns a reader positioned at the op body on status 0; a
/// worker-reported error or an id mismatch becomes `Err`.
pub fn check_response<'a>(payload: &'a [u8], expect_req_id: u64) -> Result<ByteReader<'a>, String> {
    let mut r = ByteReader::new(payload);
    let req_id = r.u64()?;
    if req_id != expect_req_id {
        return Err(format!("response for request {req_id}, expected {expect_req_id}"));
    }
    match r.u8()? {
        0 => Ok(r),
        1 => {
            let msg = String::from_utf8_lossy(r.bytes()?).into_owned();
            Err(format!("worker error: {msg}"))
        }
        s => Err(format!("unknown response status byte {s}")),
    }
}

/// The Hello response body: the worker's claimed shard placement, which
/// the coordinator cross-checks against the manifest / expected coverage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HelloAck {
    /// first global index owned by the worker (inclusive)
    pub start: usize,
    /// one past the last global index owned (exclusive)
    pub end: usize,
    /// global N the worker believes it is a shard of
    pub n: usize,
    /// flattened parameter dimension of the worker's model
    pub dim: usize,
}

impl HelloAck {
    /// Append the wire encoding to `w`.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.usize(self.start);
        w.usize(self.end);
        w.usize(self.n);
        w.usize(self.dim);
    }

    /// Decode the [`Self::encode`] layout.
    pub fn decode(r: &mut ByteReader) -> Result<Self, String> {
        Ok(HelloAck { start: r.usize()?, end: r.usize()?, n: r.usize()?, dim: r.usize()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(anchor: Option<Vec<f64>>) -> ModelSpec {
        ModelSpec {
            kind: ModelKind::Softmax,
            n: 1000,
            d: 7,
            k: 3,
            xi_const: 1.5,
            nu: 4.0,
            sigma: 0.5,
            anchor,
        }
    }

    #[test]
    fn model_spec_roundtrips_with_and_without_anchor() {
        for s in [spec(None), spec(Some(vec![0.25, -1.5, 3.0_f64.sqrt()]))] {
            let mut w = ByteWriter::new();
            s.encode(&mut w);
            let bytes = w.into_bytes();
            let mut r = ByteReader::new(&bytes);
            let got = ModelSpec::decode(&mut r).unwrap();
            r.finish().unwrap();
            assert_eq!(got, s);
        }
    }

    #[test]
    fn requests_roundtrip_through_decode() {
        let theta = vec![0.1, -0.2, 0.3];
        let idx = vec![0u32, 5, 17];
        let cases: Vec<(Vec<u8>, Request)> = vec![
            (encode_hello(1, &spec(None)), Request::Hello(spec(None))),
            (encode_set_anchor(2, &theta), Request::SetAnchor(theta.clone())),
            (
                encode_eval(3, OP_EVAL_LIK, &theta, &idx),
                Request::EvalLik { theta: theta.clone(), idx: idx.clone() },
            ),
            (
                encode_eval(4, OP_EVAL_BOTH, &theta, &idx),
                Request::EvalBoth { theta: theta.clone(), idx: idx.clone() },
            ),
            (
                encode_eval(5, OP_EVAL_LIK_GRAD_ROWS, &theta, &idx),
                Request::EvalLikGradRows { theta: theta.clone(), idx: idx.clone() },
            ),
            (
                encode_eval(6, OP_EVAL_PSEUDO_GRAD_ROWS, &theta, &idx),
                Request::EvalPseudoGradRows { theta: theta.clone(), idx: idx.clone() },
            ),
            (encode_bodyless(7, OP_PING), Request::Ping),
            (encode_bodyless(8, OP_SHUTDOWN), Request::Shutdown),
        ];
        for (i, (payload, want)) in cases.into_iter().enumerate() {
            let (req_id, got) = decode_request(&payload).unwrap();
            assert_eq!(req_id, i as u64 + 1);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn eval_payload_preserves_f64_bits() {
        // adversarial bit patterns: -0.0, subnormal, huge, tiny
        let theta = vec![-0.0, f64::MIN_POSITIVE / 4.0, 1e300, -1e-300];
        let payload = encode_eval(9, OP_EVAL_LIK, &theta, &[0]);
        let (_, req) = decode_request(&payload).unwrap();
        let Request::EvalLik { theta: got, .. } = req else { panic!("wrong op") };
        for (a, b) in theta.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn responses_unwrap_status_and_req_id() {
        let mut w = ok_response(42);
        w.f64_slice(&[1.0, 2.0]);
        let bytes = w.into_bytes();
        let mut body = check_response(&bytes, 42).unwrap();
        assert_eq!(body.f64_vec().unwrap(), vec![1.0, 2.0]);
        body.finish().unwrap();

        let err = check_response(&bytes, 41).unwrap_err();
        assert!(err.contains("expected 41"), "{err}");

        let bytes = err_response(7, "shard index out of range");
        let err = check_response(&bytes, 7).unwrap_err();
        assert!(err.contains("worker error: shard index out of range"), "{err}");
    }

    #[test]
    fn hello_ack_roundtrips() {
        let ack = HelloAck { start: 250, end: 500, n: 1000, dim: 21 };
        let mut w = ByteWriter::new();
        ack.encode(&mut w);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(HelloAck::decode(&mut r).unwrap(), ack);
        r.finish().unwrap();
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut payload = encode_bodyless(1, OP_PING);
        payload.push(0xFF);
        assert!(decode_request(&payload).is_err());
    }

    #[test]
    fn unknown_op_is_rejected() {
        let payload = encode_bodyless(1, 200);
        let err = decode_request(&payload).unwrap_err();
        assert!(err.contains("unknown request op"), "{err}");
    }
}
