//! Seeded statistical validation of sampler output against a known
//! posterior.
//!
//! The harness answers one question: *do these MCMC samples come from the
//! posterior they claim to?* It runs a battery of per-component z-tests —
//! mean, second moment, and quantile-coverage at 25/50/75% — against either
//! an analytic Gaussian posterior ([`check_against_normal`]) or a trusted
//! long reference chain ([`check_against_reference`]).
//!
//! False-positive accounting is explicit (DESIGN.md §Baselines):
//!
//! * every standard error is scaled by the series' **effective sample
//!   size**, not its raw length, so autocorrelated chains are not
//!   over-penalized. The harness takes the more conservative (smaller) of
//!   the batch-means and Geyer estimates: batch means saturates when the
//!   autocorrelation time exceeds the batch length, and a too-optimistic
//!   ESS would turn mixing noise into spurious bias flags;
//! * the rejection threshold is **Bonferroni-corrected** over the full
//!   battery (`dim × 5` tests): each |z| is compared against
//!   `Φ⁻¹(1 − α / (2·tests))`, bounding the family-wise false-positive
//!   rate of a *correct* sampler at `α`.
//!
//! Under the repo's pinned seeds a pass/fail outcome is deterministic, so a
//! check that passes once in CI passes always; `α` only calibrates how far
//! into the tail the pinned draw would have to land to flag a correct
//! sampler. "Bias detected" therefore means the observed discrepancy is
//! many standard errors beyond what chain noise at this ESS explains — the
//! operational definition used by `rust/tests/integration_baselines.rs` and
//! the head-to-head bench's bias column.

use crate::diagnostics::TraceMatrix;
use crate::util::math::{mean, normal_quantile, variance};

/// Quantile levels every check battery covers.
pub const QUANTILES: [f64; 3] = [0.25, 0.5, 0.75];

/// One z-test in a check battery.
#[derive(Clone, Debug)]
pub struct TestOutcome {
    /// θ component index the test applies to
    pub component: usize,
    /// what was compared ("mean", "second moment", "q25", "q50", "q75")
    pub statistic: &'static str,
    /// observed discrepancy in standard-error units
    pub z: f64,
}

/// Result of a full check battery.
#[derive(Clone, Debug)]
pub struct CheckReport {
    /// family-wise false-positive rate the threshold was derived from
    pub alpha: f64,
    /// Bonferroni-corrected two-sided |z| rejection threshold
    pub threshold: f64,
    /// every test in the battery (`dim × 5` entries)
    pub tests: Vec<TestOutcome>,
}

impl CheckReport {
    /// Whether every test in the battery stayed below the threshold.
    pub fn passed(&self) -> bool {
        self.tests.iter().all(|t| t.z.abs() <= self.threshold)
    }

    /// Largest |z| over the battery — the scalar "posterior-moment bias"
    /// the head-to-head bench reports per algorithm. NaN z-scores (a
    /// degenerate chain) count as infinite bias, never as evidence of
    /// correctness.
    pub fn max_abs_z(&self) -> f64 {
        self.tests
            .iter()
            .map(|t| if t.z.is_nan() { f64::INFINITY } else { t.z.abs() })
            .fold(0.0, f64::max)
    }

    /// Human-readable descriptions of every failing test.
    pub fn failures(&self) -> Vec<String> {
        self.tests
            .iter()
            .filter(|t| !(t.z.abs() <= self.threshold))
            .map(|t| {
                format!(
                    "component {} {}: |z| = {:.2} exceeds {:.2}",
                    t.component, t.statistic, t.z, self.threshold
                )
            })
            .collect()
    }
}

/// Effective sample size of a scalar series by the method of batch means
/// (`B = ⌊√T⌋` batches): `τ̂ = L·Var(batch means)/s²`, `ESS = T/τ̂`,
/// clamped to `[1, T]`. Matches the estimator the streaming diagnostics
/// use, computed here over a recorded column.
pub fn batch_means_ess(x: &[f64]) -> f64 {
    let t = x.len();
    if t < 4 {
        return t.max(1) as f64;
    }
    let b = (t as f64).sqrt().floor() as usize;
    let l = t / b;
    let used = b * l;
    let s2 = variance(&x[..used]);
    if s2.is_nan() || s2 <= 0.0 {
        return 1.0; // constant (or NaN-poisoned) chain carries no information
    }
    let batch_means: Vec<f64> = (0..b).map(|i| mean(&x[i * l..(i + 1) * l])).collect();
    let tau = (l as f64 * variance(&batch_means) / s2).max(1e-12);
    (t as f64 / tau).clamp(1.0, t as f64)
}

/// The ESS estimate the check batteries scale standard errors by: the
/// smaller of [`batch_means_ess`] and the Geyer initial-monotone-sequence
/// estimate ([`crate::diagnostics::ess_geyer`]). Conservative by
/// construction — see the module docs.
pub fn series_ess(x: &[f64]) -> f64 {
    batch_means_ess(x).min(crate::diagnostics::ess_geyer(x)).max(1.0)
}

fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn fraction_below(x: &[f64], t: f64) -> f64 {
    x.iter().filter(|&&v| v <= t).count() as f64 / x.len() as f64
}

fn bonferroni_threshold(alpha: f64, tests: usize) -> f64 {
    assert!(alpha > 0.0 && alpha < 1.0, "alpha must lie in (0, 1)");
    normal_quantile(1.0 - alpha / (2.0 * tests.max(1) as f64))
}

struct ColumnStats {
    xs: Vec<f64>,
    sorted: Vec<f64>,
    mean: f64,
    var: f64,
    ess: f64,
    m2: f64,     // second raw moment  E[x²]
    var_x2: f64, // sample variance of x²
}

impl ColumnStats {
    fn gather(trace: &TraceMatrix, j: usize) -> ColumnStats {
        let xs: Vec<f64> = trace.column_iter(j).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        let sq: Vec<f64> = xs.iter().map(|&x| x * x).collect();
        ColumnStats {
            mean: mean(&xs),
            var: variance(&xs),
            ess: series_ess(&xs),
            m2: mean(&sq),
            var_x2: variance(&sq),
            xs,
            sorted,
        }
    }
}

/// Check a chain's samples against an analytic posterior with independent
/// Gaussian marginals `θ_j ~ N(means[j], vars[j])` (the conjugate cases the
/// harness itself is validated on). The analytic side contributes zero
/// sampling error, so every standard error comes from the chain's
/// batch-means ESS alone.
///
/// Panics unless the trace is non-empty and `means`/`vars` match its
/// dimension with positive variances.
pub fn check_against_normal(
    chain: &TraceMatrix,
    means: &[f64],
    vars: &[f64],
    alpha: f64,
) -> CheckReport {
    assert!(!chain.is_empty(), "posterior check needs a recorded trace");
    assert_eq!(chain.dim(), means.len(), "means do not match trace dim");
    assert_eq!(chain.dim(), vars.len(), "vars do not match trace dim");
    assert!(vars.iter().all(|&v| v > 0.0), "analytic variances must be positive");
    let n_tests = chain.dim() * (2 + QUANTILES.len());
    let threshold = bonferroni_threshold(alpha, n_tests);
    let mut tests = Vec::with_capacity(n_tests);
    for j in 0..chain.dim() {
        let c = ColumnStats::gather(chain, j);
        let (mu, v) = (means[j], vars[j]);
        // mean: Var(θ̄) = σ²/ESS
        tests.push(TestOutcome {
            component: j,
            statistic: "mean",
            z: (c.mean - mu) / (v / c.ess).sqrt(),
        });
        // second raw moment: E[θ²] = μ² + σ², Var(θ²) = 2σ⁴ + 4μ²σ²
        let m2_true = mu * mu + v;
        let var_x2 = 2.0 * v * v + 4.0 * mu * mu * v;
        tests.push(TestOutcome {
            component: j,
            statistic: "second moment",
            z: (c.m2 - m2_true) / (var_x2 / c.ess).sqrt(),
        });
        // quantile coverage: P(θ ≤ μ + σΦ⁻¹(q)) must be q
        for (&q, stat) in QUANTILES.iter().zip(["q25", "q50", "q75"]) {
            let t = mu + v.sqrt() * normal_quantile(q);
            let se = (q * (1.0 - q) / c.ess).sqrt();
            tests.push(TestOutcome {
                component: j,
                statistic: stat,
                z: (fraction_below(&c.xs, t) - q) / se,
            });
        }
    }
    CheckReport { alpha, threshold, tests }
}

/// Check a chain's samples against a trusted reference chain of the same
/// posterior (two-sample): means, second moments, and quantile coverage
/// must agree within the noise both chains' batch-means ESS predicts.
///
/// The reference should be much longer than the chain under test — its ESS
/// enters every standard error, so a short reference widens all tolerances.
///
/// Panics unless both traces are non-empty with equal dimensions.
pub fn check_against_reference(
    chain: &TraceMatrix,
    reference: &TraceMatrix,
    alpha: f64,
) -> CheckReport {
    assert!(
        !chain.is_empty() && !reference.is_empty(),
        "posterior check needs recorded traces"
    );
    assert_eq!(chain.dim(), reference.dim(), "trace dims differ");
    let n_tests = chain.dim() * (2 + QUANTILES.len());
    let threshold = bonferroni_threshold(alpha, n_tests);
    let mut tests = Vec::with_capacity(n_tests);
    for j in 0..chain.dim() {
        let c = ColumnStats::gather(chain, j);
        let r = ColumnStats::gather(reference, j);
        tests.push(TestOutcome {
            component: j,
            statistic: "mean",
            z: (c.mean - r.mean) / (c.var / c.ess + r.var / r.ess).sqrt(),
        });
        tests.push(TestOutcome {
            component: j,
            statistic: "second moment",
            z: (c.m2 - r.m2) / (c.var_x2 / c.ess + r.var_x2 / r.ess).sqrt(),
        });
        // coverage of the reference's empirical quantiles by the chain
        for (&q, stat) in QUANTILES.iter().zip(["q25", "q50", "q75"]) {
            let t = quantile_sorted(&r.sorted, q);
            let p_ref = fraction_below(&r.xs, t);
            let se = (q * (1.0 - q) * (1.0 / c.ess + 1.0 / r.ess)).sqrt();
            tests.push(TestOutcome {
                component: j,
                statistic: stat,
                z: (fraction_below(&c.xs, t) - p_ref) / se,
            });
        }
    }
    CheckReport { alpha, threshold, tests }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::{Mala, RandomWalkMh, Sampler, SliceSampler};
    use crate::testing::targets::{GaussDataTarget, GaussTarget};
    use crate::util::Rng;

    fn run_chain(
        sampler: &mut dyn Sampler,
        target: &mut dyn crate::samplers::Target,
        iters: usize,
        burnin: usize,
        thin: usize,
        seed: u64,
    ) -> TraceMatrix {
        let mut rng = Rng::new(seed);
        let mut theta = vec![0.0; target.dim()];
        target.commit(&theta);
        let mut trace = TraceMatrix::with_capacity(theta.len(), (iters - burnin) / thin);
        for i in 0..iters {
            if i == burnin {
                sampler.freeze_adaptation();
            }
            sampler.step(target, &mut theta, &mut rng);
            if i >= burnin && (i - burnin) % thin == 0 {
                trace.push_row(&theta);
            }
        }
        trace
    }

    fn iid_normal_trace(dim: usize, rows: usize, mu: f64, sigma: f64, seed: u64) -> TraceMatrix {
        let mut rng = Rng::new(seed);
        let mut trace = TraceMatrix::with_capacity(dim, rows);
        let mut row = vec![0.0; dim];
        for _ in 0..rows {
            for v in row.iter_mut() {
                *v = mu + sigma * rng.normal();
            }
            trace.push_row(&row);
        }
        trace
    }

    #[test]
    fn batch_means_ess_tracks_iid_and_correlated_chains() {
        let mut rng = Rng::new(crate::testing::prop_seed() ^ 0xE55);
        let iid: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        let e = batch_means_ess(&iid);
        assert!(e > 4000.0 && e <= 10_000.0, "iid ESS {e}");
        // AR(1) with rho = 0.95 has tau ≈ 39
        let mut x = vec![0.0; 50_000];
        for i in 1..x.len() {
            x[i] = 0.95 * x[i - 1] + rng.normal();
        }
        let e = batch_means_ess(&x);
        let tau = x.len() as f64 / e;
        assert!(tau > 15.0 && tau < 120.0, "AR(1) tau {tau}");
        // the battery's estimate is never more optimistic than either input
        let s = series_ess(&x);
        assert!(s <= batch_means_ess(&x) && s >= 1.0);
        // degenerate inputs
        assert_eq!(batch_means_ess(&[]), 1.0);
        assert_eq!(batch_means_ess(&[1.0, 1.0, 1.0, 1.0, 1.0]), 1.0);
        assert_eq!(series_ess(&[]), 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn exact_samplers_pass_against_the_analytic_gaussian() {
        // the harness's own calibration: all three paper samplers on a
        // target with known moments must clear the battery
        let seed = crate::testing::prop_seed() ^ 0x9C;
        let dim = 3;
        let sigma = 1.3;
        let means = vec![0.0; dim];
        let vars = vec![sigma * sigma; dim];
        let samplers: Vec<(&str, Box<dyn Sampler>)> = vec![
            ("mh", Box::new(RandomWalkMh::adaptive(0.8))),
            ("mala", Box::new(Mala::adaptive(0.4))),
            ("slice", Box::new(SliceSampler::new(1.0))),
        ];
        for (name, mut s) in samplers {
            let mut target = GaussTarget::new(dim, sigma);
            let trace = run_chain(s.as_mut(), &mut target, 44_000, 4_000, 5, seed);
            let report = check_against_normal(&trace, &means, &vars, 1e-3);
            assert!(
                report.passed(),
                "{name} flagged on its own target: {:?}",
                report.failures()
            );
            assert!(report.max_abs_z() <= report.threshold);
            assert_eq!(report.tests.len(), dim * 5);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn wrong_moments_are_detected() {
        let seed = crate::testing::prop_seed() ^ 0xBAD;
        let mut s = RandomWalkMh::adaptive(0.8);
        let mut target = GaussTarget::new(2, 1.0);
        let trace = run_chain(&mut s, &mut target, 22_000, 2_000, 5, seed);
        // wrong mean
        let r = check_against_normal(&trace, &[0.5, 0.0], &[1.0, 1.0], 0.01);
        assert!(!r.passed(), "shifted mean not detected");
        assert!(!r.failures().is_empty());
        // wrong variance
        let r = check_against_normal(&trace, &[0.0, 0.0], &[4.0, 4.0], 0.01);
        assert!(!r.passed(), "inflated variance not detected");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn two_sample_check_passes_same_and_flags_shifted_references() {
        let seed = crate::testing::prop_seed() ^ 0x25A;
        let chain = iid_normal_trace(2, 8_000, 0.0, 1.0, seed);
        let reference = iid_normal_trace(2, 40_000, 0.0, 1.0, seed ^ 1);
        let r = check_against_reference(&chain, &reference, 1e-3);
        assert!(r.passed(), "same-distribution pair flagged: {:?}", r.failures());
        let shifted = iid_normal_trace(2, 40_000, 0.4, 1.0, seed ^ 2);
        let r = check_against_reference(&chain, &shifted, 0.01);
        assert!(!r.passed(), "0.4σ shift not detected");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn conjugate_data_posterior_clears_the_battery() {
        // end-to-end on a data-factorized posterior: RW-MH over
        // GaussDataTarget vs its closed-form conjugate moments
        let seed = crate::testing::prop_seed() ^ 0xC0;
        let mut rng = Rng::new(seed);
        let mut target = GaussDataTarget::synth(300, 0.7, 1.0, 25.0, &mut rng);
        let sd = target.posterior_var().sqrt();
        let mut s = RandomWalkMh::adaptive(2.5 * sd);
        let trace = run_chain(&mut s, &mut target, 44_000, 4_000, 5, seed ^ 3);
        let means = vec![target.posterior_mean()];
        let vars = vec![target.posterior_var()];
        let r = check_against_normal(&trace, &means, &vars, 1e-3);
        assert!(r.passed(), "conjugate posterior flagged: {:?}", r.failures());
    }

    #[test]
    fn report_accounting_is_consistent() {
        let trace = iid_normal_trace(1, 512, 0.0, 1.0, 7);
        let r = check_against_normal(&trace, &[0.0], &[1.0], 0.01);
        assert_eq!(r.tests.len(), 5);
        // Bonferroni: threshold grows with the battery size
        let wide = bonferroni_threshold(0.01, 50);
        let narrow = bonferroni_threshold(0.01, 5);
        assert!(wide > narrow && narrow > bonferroni_threshold(0.05, 5));
        // NaN z-scores never pass silently
        let mut bad = r.clone();
        bad.tests[0].z = f64::NAN;
        assert!(!bad.passed());
        assert_eq!(bad.max_abs_z(), f64::INFINITY);
    }

    #[test]
    #[should_panic(expected = "trace dims differ")]
    fn mismatched_dims_are_rejected() {
        let a = iid_normal_trace(1, 64, 0.0, 1.0, 1);
        let b = iid_normal_trace(2, 64, 0.0, 1.0, 2);
        check_against_reference(&a, &b, 0.01);
    }
}
