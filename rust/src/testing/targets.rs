//! Analytic targets for sampler validation.
//!
//! [`GaussTarget`] is the standalone isotropic Gaussian the θ-sampler unit
//! tests have always run against (promoted here from a test-only module so
//! the statistical harness in [`super::posterior_check`] and the integration
//! suites can validate against a posterior with known moments).
//!
//! [`GaussDataTarget`] is the smallest *data-factorized* posterior: N scalar
//! observations `y_i ~ N(θ, σ²)` under a `N(0, τ²)` prior, with the conjugate
//! posterior available in closed form. It implements both [`Target`] and
//! [`SubsampleTarget`], so the approximate samplers (SGLD, austerity MH) can
//! be unit-tested against exact moments without a model/backend stack.

use crate::samplers::target::{SubsampleTarget, Target};

/// Isotropic zero-mean Gaussian target `N(0, σ² I)` with analytic moments.
pub struct GaussTarget {
    /// parameter dimension
    pub dim: usize,
    /// per-component standard deviation
    pub sigma: f64,
    theta: Vec<f64>,
    cur: f64,
}

impl GaussTarget {
    /// A `dim`-dimensional N(0, σ²I) target.
    pub fn new(dim: usize, sigma: f64) -> Self {
        GaussTarget { dim, sigma, theta: vec![0.0; dim], cur: 0.0 }
    }
    fn logp(&self, t: &[f64]) -> f64 {
        -0.5 * t.iter().map(|x| x * x).sum::<f64>() / (self.sigma * self.sigma)
    }
}

impl Target for GaussTarget {
    fn dim(&self) -> usize {
        self.dim
    }
    fn log_density(&mut self, theta: &[f64]) -> f64 {
        self.logp(theta)
    }
    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        for (g, t) in grad.iter_mut().zip(theta) {
            *g = -t / (self.sigma * self.sigma);
        }
        self.logp(theta)
    }
    fn commit(&mut self, theta: &[f64]) {
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur = self.logp(theta);
    }
    fn current_log_density(&self) -> f64 {
        self.cur
    }
}

/// Scalar conjugate-Gaussian data posterior: `y_i ~ N(θ, σ²)`, `θ ~ N(0, τ²)`.
///
/// The posterior is `N(m, v)` with precision `P = n/σ² + 1/τ²`,
/// `v = 1/P`, `m = (Σy/σ²)/P` — see [`Self::posterior_mean`] /
/// [`Self::posterior_var`]. Likelihood factors are served per-datum through
/// [`SubsampleTarget`], which is what lets SGLD/austerity unit tests check
/// their estimators against exact moments.
pub struct GaussDataTarget {
    y: Vec<f64>,
    sigma2: f64,
    tau2: f64,
    theta: Vec<f64>,
    cur: f64,
}

impl GaussDataTarget {
    /// Build from observations `y` with noise variance `sigma2` and prior
    /// variance `tau2`.
    pub fn new(y: Vec<f64>, sigma2: f64, tau2: f64) -> Self {
        assert!(!y.is_empty() && sigma2 > 0.0 && tau2 > 0.0);
        GaussDataTarget { y, sigma2, tau2, theta: vec![0.0], cur: 0.0 }
    }

    /// Synthesize `n` observations from `N(mu_true, sigma2)` under `rng`.
    pub fn synth(n: usize, mu_true: f64, sigma2: f64, tau2: f64, rng: &mut crate::util::Rng) -> Self {
        let y = (0..n).map(|_| mu_true + sigma2.sqrt() * rng.normal()).collect();
        Self::new(y, sigma2, tau2)
    }

    /// Exact posterior mean.
    pub fn posterior_mean(&self) -> f64 {
        let sum_y: f64 = self.y.iter().sum();
        (sum_y / self.sigma2) / self.posterior_precision()
    }

    /// Exact posterior variance.
    pub fn posterior_var(&self) -> f64 {
        1.0 / self.posterior_precision()
    }

    fn posterior_precision(&self) -> f64 {
        self.y.len() as f64 / self.sigma2 + 1.0 / self.tau2
    }

    fn log_lik_one(&self, theta: f64, i: usize) -> f64 {
        let d = self.y[i] - theta;
        -0.5 * d * d / self.sigma2
    }

    fn full_logp(&self, theta: f64) -> f64 {
        let lik: f64 = (0..self.y.len()).map(|i| self.log_lik_one(theta, i)).sum();
        -0.5 * theta * theta / self.tau2 + lik
    }
}

impl Target for GaussDataTarget {
    fn dim(&self) -> usize {
        1
    }
    fn log_density(&mut self, theta: &[f64]) -> f64 {
        self.full_logp(theta[0])
    }
    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        let t = theta[0];
        let dlik: f64 = self.y.iter().map(|&y| (y - t) / self.sigma2).sum();
        grad[0] = -t / self.tau2 + dlik;
        self.full_logp(t)
    }
    fn commit(&mut self, theta: &[f64]) {
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur = self.full_logp(theta[0]);
    }
    fn current_log_density(&self) -> f64 {
        self.cur
    }
    fn as_subsample(&mut self) -> Option<&mut dyn SubsampleTarget> {
        Some(self)
    }
}

impl SubsampleTarget for GaussDataTarget {
    fn n_data(&self) -> usize {
        self.y.len()
    }
    fn minibatch_log_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
        ll.clear();
        ll.extend(idx.iter().map(|&i| self.log_lik_one(theta[0], i as usize)));
    }
    fn minibatch_grad_acc(&mut self, theta: &[f64], idx: &[u32], grad: &mut [f64]) -> f64 {
        let t = theta[0];
        let mut ll_sum = 0.0;
        for &i in idx {
            let d = self.y[i as usize] - t;
            grad[0] += d / self.sigma2;
            ll_sum += -0.5 * d * d / self.sigma2;
        }
        ll_sum
    }
    fn prior_log_density(&self, theta: &[f64]) -> f64 {
        -0.5 * theta[0] * theta[0] / self.tau2
    }
    fn prior_grad_acc(&self, theta: &[f64], grad: &mut [f64]) {
        grad[0] += -theta[0] / self.tau2;
    }
    fn set_state(&mut self, theta: &[f64], log_density_estimate: f64) {
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur = log_density_estimate;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauss_data_conjugate_moments_consistent() {
        let mut rng = crate::util::Rng::new(1);
        let t = GaussDataTarget::synth(200, 0.8, 1.0, 10.0, &mut rng);
        // With n=200 and flat-ish prior the posterior mean tracks ȳ.
        let ybar: f64 = t.y.iter().sum::<f64>() / t.y.len() as f64;
        assert!((t.posterior_mean() - ybar).abs() < 0.01);
        assert!((t.posterior_var() - 1.0 / 200.05).abs() < 1e-12);
    }

    #[test]
    fn subsample_full_batch_matches_target() {
        let mut rng = crate::util::Rng::new(2);
        let mut t = GaussDataTarget::synth(50, -0.3, 0.7, 4.0, &mut rng);
        let theta = [0.4];
        let full = t.log_density(&theta);
        let idx: Vec<u32> = (0..50).collect();
        let mut ll = Vec::new();
        t.minibatch_log_lik(&theta, &idx, &mut ll);
        let sum: f64 = t.prior_log_density(&theta) + ll.iter().sum::<f64>();
        assert!((full - sum).abs() < 1e-12);
        // gradient path agrees with Target::grad_log_density
        let mut g_full = [0.0];
        t.grad_log_density(&theta, &mut g_full);
        let mut g_sub = [0.0];
        let ll_sum = t.minibatch_grad_acc(&theta, &idx, &mut g_sub);
        t.prior_grad_acc(&theta, &mut g_sub);
        assert!((g_full[0] - g_sub[0]).abs() < 1e-12);
        assert!((ll_sum - ll.iter().sum::<f64>()).abs() < 1e-12);
    }
}
