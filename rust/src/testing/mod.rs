//! Mini property-based testing substrate (offline stand-in for `proptest`).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs from
//! `gen`; on failure it reports the seed and the failing case, so the run is
//! reproducible with `FIREFLY_PROP_SEED=<seed>`. Generators are plain
//! closures over [`crate::util::Rng`], composable with ordinary Rust.

use crate::util::Rng;

pub mod posterior_check;
pub mod targets;

/// The pinned seed property/statistical tests run under: the
/// `FIREFLY_PROP_SEED` environment variable when set (to reproduce a reported
/// failure), else a fixed default so CI is deterministic.
pub fn prop_seed() -> u64 {
    std::env::var("FIREFLY_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1EF_17u64)
}

/// Run a property over `cases` generated inputs. Panics with seed + debug
/// dump of the first failing case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generator: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    let seed = prop_seed();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generator(&mut rng);
        if !property(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}).\ninput: {input:#?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generator: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = prop_seed();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generator(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Round-trip a dataset through a uniquely-named temporary `.fbin` file and
/// reopen it out of core with `cache` — test support for the hotpath /
/// byte-identity binaries, so each doesn't hand-roll the write/open/cleanup
/// sequence. The temp file is unlinked before returning; the open handle
/// keeps it readable (unix semantics — the test suites run on linux CI).
pub fn fbin_roundtrip(
    data: &crate::data::AnyData,
    cache: crate::data::store::BlockCacheConfig,
) -> crate::data::AnyData {
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    let path = std::env::temp_dir()
        .join(format!(
            "firefly_fbin_rt_{}_{}.fbin",
            std::process::id(),
            UNIQ.fetch_add(1, Ordering::Relaxed)
        ))
        .to_string_lossy()
        .into_owned();
    crate::data::fbin::write_fbin(&path, data).expect("write .fbin round-trip file");
    let out = crate::data::fbin::open_fbin(&path, cache).expect("reopen .fbin round-trip file");
    let _ = std::fs::remove_file(&path);
    out
}

/// Common generators.
pub mod gen {
    use crate::util::Rng;

    /// `len` iid N(0, scale²) draws.
    pub fn vec_normal(rng: &mut Rng, len: usize, scale: f64) -> Vec<f64> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    /// Uniform integer in [lo, hi).
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo)
    }

    /// `len` iid uniform ±1 values.
    pub fn signs(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 50, |r| (r.f64(), r.f64()), |&(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_name() {
        check("always-false", 10, |r| r.f64(), |_| false);
    }

    #[test]
    fn generators_cover_range() {
        let mut r = crate::util::Rng::new(0);
        for _ in 0..100 {
            let k = gen::usize_in(&mut r, 3, 10);
            assert!((3..10).contains(&k));
        }
        let s = gen::signs(&mut r, 1000);
        assert!(s.iter().all(|&x| x == 1.0 || x == -1.0));
        let pos = s.iter().filter(|&&x| x > 0.0).count();
        assert!((300..700).contains(&pos));
    }
}
