//! Micro/macro benchmark substrate (offline stand-in for `criterion`).
//!
//! `Bench::new("name").run(..)` does warmup, then timed samples, and reports
//! median / mean / std / min in a criterion-like one-liner. The table/figure
//! benches in `benches/` are *macro* harnesses that use [`Report`] to print
//! the paper's rows; `benches/microbench.rs` uses the timing half for the
//! §Perf hot-path iteration.

use crate::util::Timer;

/// Timing samples of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// benchmark name
    pub name: String,
    /// seconds per iteration, one entry per sample
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Median seconds per iteration.
    pub fn median_s(&self) -> f64 {
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }
    /// Mean seconds per iteration.
    pub fn mean_s(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Standard deviation of the samples.
    pub fn std_s(&self) -> f64 {
        let m = self.mean_s();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }
    /// Fastest sample.
    pub fn min_s(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Print the criterion-style one-liner.
    pub fn report(&self) {
        println!(
            "{:<44} median {:>12} mean {:>12} ± {:>10} min {:>12}",
            self.name,
            fmt_time(self.median_s()),
            fmt_time(self.mean_s()),
            fmt_time(self.std_s()),
            fmt_time(self.min_s()),
        );
    }
}

/// Format seconds with an auto-selected unit (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Builder for one warm-up + timed-samples benchmark run.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    samples: usize,
    iters_per_sample: usize,
}

impl Bench {
    /// Benchmark with defaults: 3 warm-up iters, 10 samples, 1 iter/sample.
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), warmup_iters: 3, samples: 10, iters_per_sample: 1 }
    }
    /// Set the warm-up iteration count.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }
    /// Set the number of timed samples (min 1).
    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }
    /// Set how many iterations each timed sample averages over (min 1).
    pub fn iters_per_sample(mut self, n: usize) -> Self {
        self.iters_per_sample = n.max(1);
        self
    }

    /// Time `f`, print a criterion-style line, return the samples.
    pub fn run(self, mut f: impl FnMut()) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Timer::start();
            for _ in 0..self.iters_per_sample {
                f();
            }
            samples.push(t.elapsed_secs() / self.iters_per_sample as f64);
        }
        let res = BenchResult { name: self.name, samples };
        res.report();
        res
    }
}

/// Plain-text table printer for paper-style reports (Table 1, ablations).
pub struct Report {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    /// Empty table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Report {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Print the table with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("| {:<w$} ", c, w = w));
            }
            s.push('|');
            println!("{s}");
        };
        line(&self.headers);
        println!("|{}|", widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            line(row);
        }
    }

    /// Also emit as CSV (for the figure series).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// ASCII line plot for quick visual checks of figure series in the terminal.
pub fn ascii_plot(title: &str, series: &[(&str, &[f64])], width: usize, height: usize) {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    let maxlen = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    for (_, s) in series {
        for &v in *s {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    if !lo.is_finite() || maxlen < 2 {
        println!("[{title}: no finite data]");
        return;
    }
    if hi == lo {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#'];
    for (si, (_, s)) in series.iter().enumerate() {
        for (i, &v) in s.iter().enumerate() {
            if !v.is_finite() {
                continue;
            }
            let xpix = i * (width - 1) / (maxlen - 1).max(1);
            let ypix = ((v - lo) / (hi - lo) * (height - 1) as f64).round() as usize;
            grid[height - 1 - ypix.min(height - 1)][xpix] = marks[si % marks.len()];
        }
    }
    println!("\n-- {title} --  [{lo:.4}, {hi:.4}]");
    for row in grid {
        println!("  |{}", row.iter().collect::<String>());
    }
    println!("  +{}", "-".repeat(width));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()], n))
        .collect();
    println!("   {}", legend.join("   "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = Bench::new("noop").warmup(1).samples(5).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.min_s() >= 0.0);
        assert!(r.median_s() >= r.min_s());
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(3e-9).ends_with("ns"));
        assert!(fmt_time(3e-6).ends_with("µs"));
        assert!(fmt_time(3e-3).ends_with("ms"));
        assert!(fmt_time(3.0).ends_with('s'));
    }

    #[test]
    fn report_roundtrip_csv() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(&["1".into(), "2".into()]);
        let path = std::env::temp_dir().join("firefly_report_test.csv");
        r.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn ascii_plot_does_not_panic() {
        let s: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        ascii_plot("sin", &[("s", &s)], 40, 8);
        ascii_plot("empty", &[("e", &[])], 40, 8);
    }
}
