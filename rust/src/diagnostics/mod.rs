//! MCMC output analysis: autocovariance, effective sample size (Geyer's
//! initial monotone positive sequence — the estimator family R-CODA's
//! `effectiveSize` uses, which the paper reports), split-R̂, and the flat
//! [`TraceMatrix`] θ-trace storage the chain driver records into.
//!
//! For chains too long to keep an O(iters × dim) trace, the [`streaming`]
//! submodule maintains the same quantities online in O(dim) memory
//! (Welford moments, batch-means ESS, split-R̂ half inputs).

pub mod streaming;

pub use streaming::{BrightStats, StreamingStats, StreamingSummary};

use crate::util::math::{mean, variance};

/// Flat row-major θ-trace: `n_rows × dim` samples in one contiguous
/// allocation. Replaces the old `Vec<Vec<f64>>` trace (one boxed row per
/// recorded iteration): the chain driver reserves the whole trace once and
/// `push_row` is a plain `memcpy` into the tail — no per-iteration
/// allocation — while the diagnostics read columns through [`Self::column_iter`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceMatrix {
    dim: usize,
    data: Vec<f64>,
}

impl TraceMatrix {
    /// Empty trace over `dim`-vectors.
    pub fn new(dim: usize) -> Self {
        TraceMatrix { dim, data: Vec::new() }
    }

    /// Empty trace with room for `rows` samples (no reallocation until then).
    pub fn with_capacity(dim: usize, rows: usize) -> Self {
        TraceMatrix { dim, data: Vec::with_capacity(dim * rows) }
    }

    /// Append one θ sample. The first row fixes `dim` when the trace was
    /// default-constructed.
    pub fn push_row(&mut self, row: &[f64]) {
        if self.dim == 0 && self.data.is_empty() {
            self.dim = row.len();
        }
        assert_eq!(row.len(), self.dim, "trace row length mismatch");
        self.data.extend_from_slice(row);
    }

    /// Number of components per sample.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of recorded samples.
    pub fn n_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.data.len() / self.dim
        }
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The i-th recorded sample.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Iterate over samples (rows).
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.dim.max(1))
    }

    /// Strided view of component `j` across all samples.
    pub fn column_iter(&self, j: usize) -> impl Iterator<Item = f64> + '_ {
        assert!(j < self.dim);
        self.data.iter().skip(j).step_by(self.dim).copied()
    }

    /// Copy component `j` into `out` (cleared first) — the contiguous buffer
    /// the scalar ESS/R̂ estimators need.
    pub fn column_into(&self, j: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.column_iter(j));
    }

    /// The raw row-major backing slice (`n_rows × dim` values) — what the
    /// checkpoint layer serializes.
    pub fn raw(&self) -> &[f64] {
        &self.data
    }

    /// Overwrite this trace with checkpointed raw contents (keeps the
    /// existing capacity, so restoring into a pre-reserved trace does not
    /// reallocate when the payload fits).
    pub fn restore_raw(&mut self, dim: usize, vals: &[f64]) -> Result<(), String> {
        if dim == 0 && !vals.is_empty() {
            return Err("trace payload with zero dim".to_string());
        }
        if dim > 0 && vals.len() % dim != 0 {
            return Err(format!(
                "trace payload of {} values is not a multiple of dim {dim}",
                vals.len()
            ));
        }
        self.dim = dim;
        self.data.clear();
        self.data.extend_from_slice(vals);
        Ok(())
    }
}

/// Autocovariance at lags 0..maxlag (biased, 1/T normalization, standard for
/// ESS estimation).
pub fn autocovariance(x: &[f64], maxlag: usize) -> Vec<f64> {
    let t = x.len();
    let m = mean(x);
    let maxlag = maxlag.min(t.saturating_sub(1));
    let mut acov = vec![0.0; maxlag + 1];
    for (lag, a) in acov.iter_mut().enumerate() {
        let mut s = 0.0;
        for i in 0..t - lag {
            s += (x[i] - m) * (x[i + lag] - m);
        }
        *a = s / t as f64;
    }
    acov
}

/// Normalized autocorrelation function.
pub fn autocorrelation(x: &[f64], maxlag: usize) -> Vec<f64> {
    let acov = autocovariance(x, maxlag);
    let c0 = acov[0];
    if c0 <= 0.0 {
        return vec![0.0; acov.len()];
    }
    acov.iter().map(|&c| c / c0).collect()
}

/// Effective sample size via Geyer (1992) initial monotone positive pair
/// sequence: sum Γ_m = γ_{2m} + γ_{2m+1} while positive and non-increasing.
pub fn ess_geyer(x: &[f64]) -> f64 {
    let t = x.len();
    if t < 4 {
        return t as f64;
    }
    let maxlag = (t - 1).min(2 * ((t as f64).sqrt() as usize) + 200);
    let acov = autocovariance(x, maxlag);
    let c0 = acov[0];
    if c0 <= 1e-300 {
        // constant chain: no information
        return 1.0;
    }
    let mut sum_pairs = 0.0;
    let mut prev = f64::INFINITY;
    let mut m = 0;
    loop {
        let i = 2 * m;
        if i + 1 >= acov.len() {
            break;
        }
        let gamma = acov[i] + acov[i + 1];
        if gamma <= 0.0 {
            break;
        }
        let gamma = gamma.min(prev); // initial monotone sequence
        // m = 0 pair includes lag 0; handle via the tau formula below
        sum_pairs += gamma;
        prev = gamma;
        m += 1;
    }
    // tau = -1 + 2 * sum_m Gamma_m / c0   (Geyer 1992, eq. 3.8-ish)
    let tau = (-1.0 + 2.0 * sum_pairs / c0).max(1.0 / t as f64);
    (t as f64 / tau).min(t as f64)
}

/// ESS per 1000 iterations — the unit Table 1 reports.
pub fn ess_per_1000(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    ess_geyer(x) * 1000.0 / x.len() as f64
}

/// Minimum component-wise ESS of a θ-trace (rows = iterations).
pub fn ess_min_components(trace: &TraceMatrix) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    let mut min_ess = f64::INFINITY;
    let mut comp = Vec::with_capacity(trace.n_rows());
    for j in 0..trace.dim() {
        trace.column_into(j, &mut comp);
        min_ess = min_ess.min(ess_geyer(&comp));
    }
    min_ess
}

/// Minimum component-wise ESS per 1000 recorded iterations — the θ-trace
/// analogue of [`ess_per_1000`], and the single source of truth for the
/// Table-1 ESS column (`engine::experiment::TableRow` routes through this).
pub fn ess_per_1000_min_components(trace: &TraceMatrix) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    ess_min_components(trace) * 1000.0 / trace.n_rows() as f64
}

/// Split-R̂ (Gelman–Rubin with halved chains) over borrowed per-chain
/// scalar series — the core implementation; nothing is copied.
pub fn split_rhat_slices(chains: &[&[f64]]) -> f64 {
    let mut halves: Vec<&[f64]> = Vec::new();
    for c in chains {
        let h = c.len() / 2;
        if h < 2 {
            return f64::NAN;
        }
        halves.push(&c[..h]);
        halves.push(&c[h..2 * h]);
    }
    let m = halves.len() as f64;
    let n = halves[0].len() as f64;
    let means: Vec<f64> = halves.iter().map(|h| mean(h)).collect();
    let vars: Vec<f64> = halves.iter().map(|h| variance(h)).collect();
    let grand = mean(&means);
    let b = n / (m - 1.0) * means.iter().map(|&mu| (mu - grand) * (mu - grand)).sum::<f64>();
    let w = mean(&vars);
    if w <= 0.0 {
        return f64::NAN;
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

/// [`split_rhat_slices`] over owned per-chain series (convenience wrapper).
pub fn split_rhat(chains: &[Vec<f64>]) -> f64 {
    let refs: Vec<&[f64]> = chains.iter().map(|c| c.as_slice()).collect();
    split_rhat_slices(&refs)
}

/// Worst-case (max over θ components) split-R̂ across replica chains.
/// `traces[r]` is replica r's post-burnin θ trace (rows = iterations).
/// Returns NaN with fewer than 2 chains, traces too short to halve, or no
/// component with positive within-chain variance.
///
/// Component columns are gathered into ONE flat `chains × rows` buffer
/// reused across components (finishing the PR 2 trace flattening: the old
/// assembly boxed a fresh `Vec<Vec<f64>>` of full columns per component).
/// Traces of unequal length are truncated to the shortest (replicas always
/// record equal lengths).
pub fn split_rhat_max_components(traces: &[&TraceMatrix]) -> f64 {
    if traces.len() < 2 || traces.iter().any(|t| t.n_rows() < 4) {
        return f64::NAN;
    }
    let rows = traces.iter().map(|t| t.n_rows()).min().unwrap();
    let d = traces[0].dim();
    let mut flat = vec![0.0; traces.len() * rows];
    let mut worst = f64::NEG_INFINITY;
    for j in 0..d {
        for (c, t) in traces.iter().enumerate() {
            for (dst, v) in flat[c * rows..(c + 1) * rows]
                .iter_mut()
                .zip(t.column_iter(j))
            {
                *dst = v;
            }
        }
        let refs: Vec<&[f64]> = flat.chunks_exact(rows).collect();
        let r = split_rhat_slices(&refs);
        if r.is_finite() {
            worst = worst.max(r);
        }
    }
    if worst == f64::NEG_INFINITY {
        f64::NAN
    } else {
        worst
    }
}

/// Pooled effective sample size across independent replicas: the per-chain
/// minimum-component ESS summed over chains (independent chains contribute
/// additive information).
pub fn pooled_ess_min_components(traces: &[&TraceMatrix]) -> f64 {
    traces.iter().map(|t| ess_min_components(t)).sum()
}

/// Summary of a scalar trace.
#[derive(Clone, Debug)]
pub struct Summary {
    /// sample mean
    pub mean: f64,
    /// sample standard deviation
    pub std: f64,
    /// Geyer effective sample size
    pub ess: f64,
    /// ESS per 1000 iterations (Table-1 unit)
    pub ess_per_1000: f64,
}

/// Mean / std / ESS summary of a scalar trace.
pub fn summarize(x: &[f64]) -> Summary {
    Summary {
        mean: mean(x),
        std: variance(x).sqrt(),
        ess: ess_geyer(x),
        ess_per_1000: ess_per_1000(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn iid_chain_has_ess_close_to_t() {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..8000).map(|_| rng.normal()).collect();
        let ess = ess_geyer(&x);
        assert!(ess > 5500.0, "iid ESS {ess}");
        assert!(ess <= 8000.0);
    }

    #[test]
    fn ar1_chain_ess_matches_theory() {
        // AR(1) with coefficient rho has tau = (1+rho)/(1-rho).
        let rho: f64 = 0.9;
        let mut rng = Rng::new(2);
        let t = 200_000;
        let mut x = vec![0.0; t];
        for i in 1..t {
            x[i] = rho * x[i - 1] + (1.0 - rho * rho).sqrt() * rng.normal();
        }
        let tau_true = (1.0 + rho) / (1.0 - rho); // 19
        let ess = ess_geyer(&x);
        let tau_est = t as f64 / ess;
        assert!(
            (tau_est - tau_true).abs() / tau_true < 0.2,
            "tau est {tau_est} vs {tau_true}"
        );
    }

    #[test]
    fn acf_of_ar1_decays_geometrically() {
        let rho: f64 = 0.7;
        let mut rng = Rng::new(3);
        let t = 100_000;
        let mut x = vec![0.0; t];
        for i in 1..t {
            x[i] = rho * x[i - 1] + rng.normal();
        }
        let acf = autocorrelation(&x, 5);
        for lag in 1..=5 {
            let expect = rho.powi(lag as i32);
            assert!(
                (acf[lag] - expect).abs() < 0.05,
                "lag {lag}: {} vs {expect}",
                acf[lag]
            );
        }
    }

    #[test]
    fn constant_chain_degenerates_gracefully() {
        let x = vec![3.0; 100];
        assert!(ess_geyer(&x) >= 1.0);
        assert!(ess_geyer(&x).is_finite());
    }

    #[test]
    fn rhat_near_one_for_same_distribution() {
        let mut rng = Rng::new(4);
        let chains: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..4000).map(|_| rng.normal()).collect())
            .collect();
        let r = split_rhat(&chains);
        assert!((r - 1.0).abs() < 0.02, "rhat {r}");
        // the borrowed-slice core is the same computation, bit for bit
        let refs: Vec<&[f64]> = chains.iter().map(|c| c.as_slice()).collect();
        assert_eq!(split_rhat_slices(&refs).to_bits(), r.to_bits());
    }

    #[test]
    fn rhat_large_for_disjoint_chains() {
        let mut rng = Rng::new(5);
        let c1: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let c2: Vec<f64> = (0..2000).map(|_| rng.normal() + 10.0).collect();
        let r = split_rhat(&[c1, c2]);
        assert!(r > 3.0, "rhat {r}");
    }

    fn trace_from_rows(rows: &[Vec<f64>]) -> TraceMatrix {
        let mut t = TraceMatrix::new(rows.first().map_or(0, |r| r.len()));
        for r in rows {
            t.push_row(r);
        }
        t
    }

    #[test]
    fn trace_matrix_rows_and_columns() {
        let mut t = TraceMatrix::with_capacity(3, 2);
        t.push_row(&[1.0, 2.0, 3.0]);
        t.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.dim(), 3);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.column_iter(1).collect::<Vec<f64>>(), vec![2.0, 5.0]);
        let mut col = Vec::new();
        t.column_into(2, &mut col);
        assert_eq!(col, vec![3.0, 6.0]);
        let rows: Vec<&[f64]> = t.rows().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], &[1.0, 2.0, 3.0]);
        // default-constructed trace learns dim from the first row
        let mut d = TraceMatrix::default();
        assert!(d.is_empty());
        assert_eq!(d.n_rows(), 0);
        d.push_row(&[7.0, 8.0]);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.n_rows(), 1);
    }

    #[test]
    fn ess_per_1000_min_components_matches_inline_formula() {
        // Pins agreement between the shared helper and the computation
        // TableRow used to inline (ess_min_components * 1000 / rows).
        let mut rng = Rng::new(8);
        let rows: Vec<Vec<f64>> = (0..2000).map(|_| vec![rng.normal(), rng.normal()]).collect();
        let t = trace_from_rows(&rows);
        let inline = ess_min_components(&t) * 1000.0 / t.n_rows() as f64;
        let helper = ess_per_1000_min_components(&t);
        assert!((inline - helper).abs() < 1e-12, "{inline} vs {helper}");
        // empty-trace guard: 0, not NaN
        assert_eq!(ess_per_1000_min_components(&TraceMatrix::default()), 0.0);
        assert_eq!(ess_min_components(&TraceMatrix::new(3)), 0.0);
    }

    #[test]
    fn rhat_max_components_and_pooled_ess() {
        let mut rng = Rng::new(7);
        let well_mixed: Vec<Vec<Vec<f64>>> = (0..4)
            .map(|_| (0..3000).map(|_| vec![rng.normal(), rng.normal()]).collect())
            .collect();
        let mats: Vec<TraceMatrix> = well_mixed.iter().map(|t| trace_from_rows(t)).collect();
        let refs: Vec<&TraceMatrix> = mats.iter().collect();
        let r = split_rhat_max_components(&refs);
        assert!((r - 1.0).abs() < 0.05, "rhat {r}");
        let pooled = pooled_ess_min_components(&refs);
        let singles: f64 = refs.iter().map(|t| ess_min_components(t)).sum();
        assert!((pooled - singles).abs() < 1e-9);
        assert!(pooled > 6000.0, "pooled ESS {pooled}");

        // one component disagrees across chains -> large max-R̂
        let mut shifted = well_mixed.clone();
        for row in shifted[0].iter_mut() {
            row[1] += 8.0;
        }
        let mats: Vec<TraceMatrix> = shifted.iter().map(|t| trace_from_rows(t)).collect();
        let refs: Vec<&TraceMatrix> = mats.iter().collect();
        assert!(split_rhat_max_components(&refs) > 2.0);

        // degenerate inputs
        assert!(split_rhat_max_components(&refs[..1]).is_nan());
        let tiny = trace_from_rows(&vec![vec![1.0]; 3]);
        assert!(split_rhat_max_components(&[&tiny, &tiny]).is_nan());
    }

    #[test]
    fn ess_per_1000_unit() {
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let v = ess_per_1000(&x);
        assert!((v - ess_geyer(&x)).abs() < 1e-9);
    }
}
