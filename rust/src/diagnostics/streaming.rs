//! Bounded-memory (O(dim)) streaming chain statistics.
//!
//! The trace-based estimators in [`crate::diagnostics`] need the whole
//! O(iters × dim) θ trace in memory — fine for paper-scale runs, hopeless
//! for `--iters 10_000_000` production chains. This module maintains the
//! same quantities *online*, in O(dim) memory independent of chain length:
//!
//! * per-component **Welford moments** (mean / unbiased variance, the same
//!   n−1 normalization as [`crate::util::math::variance`]);
//! * **batch-means ESS** inputs: non-overlapping batches of size
//!   ⌈√rows⌉, a Welford accumulator over the batch means, and the classic
//!   estimator τ̂ = B·Var(batch means)/s², ESS = rows/τ̂;
//! * **split-R̂ inputs**: separate Welford accumulators over the first and
//!   second halves of the (known-length) post-burn-in window, combined with
//!   the same formula as [`crate::diagnostics::split_rhat_slices`];
//! * the per-iteration **bright-count summary** (min / mean / max / last)
//!   the experiment report prints.
//!
//! Accuracy contract (asserted by `rust/tests/integration_checkpoint.rs`):
//! streaming mean/variance agree with the batch `TraceMatrix`-derived
//! values to ≤ 1e-8 relative error, and the halves-based split-R̂ agrees
//! with [`crate::diagnostics::split_rhat_slices`] over the materialized
//! halves to ≤ 1e-6 relative. The estimators are not bit-equal to their
//! batch counterparts (different summation order); they ARE bit-reproducible
//! run-to-run, which is what the checkpoint/resume identity guarantee needs.
//!
//! Everything here is checkpointable ([`StreamingStats::save_state`]) and
//! allocation-free after construction — the streaming observer rides inside
//! the zero-alloc steady-state window (DESIGN.md §Perf).

use crate::util::codec::{ByteReader, ByteWriter};

/// Per-component Welford accumulator over `dim`-vectors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WelfordVec {
    n: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl WelfordVec {
    /// Zeroed accumulator over `dim` components.
    pub fn new(dim: usize) -> Self {
        WelfordVec { n: 0, mean: vec![0.0; dim], m2: vec![0.0; dim] }
    }

    /// Number of components.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Number of vectors accumulated.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Fold one vector in (O(dim), no allocation).
    pub fn update(&mut self, x: &[f64]) {
        debug_assert_eq!(x.len(), self.mean.len());
        self.n += 1;
        let n = self.n as f64;
        for j in 0..self.mean.len() {
            let delta = x[j] - self.mean[j];
            self.mean[j] += delta / n;
            self.m2[j] += delta * (x[j] - self.mean[j]);
        }
    }

    /// Running mean of component `j` (NaN before the first update).
    pub fn mean(&self, j: usize) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean[j]
        }
    }

    /// Running means (zeros before the first update).
    pub fn means(&self) -> &[f64] {
        &self.mean
    }

    /// Unbiased (n−1) sample variance of component `j` (NaN below 2).
    pub fn var(&self, j: usize) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2[j] / (self.n - 1) as f64
        }
    }

    /// Serialize (count + mean + M2, bit-exact).
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.u64(self.n);
        w.f64_slice(&self.mean);
        w.f64_slice(&self.m2);
    }

    /// Restore [`Self::save_state`] bytes in place (keeps capacity;
    /// dimension must match).
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let dim = self.mean.len();
        self.n = r.u64()?;
        r.f64_slice_into(&mut self.mean)?;
        r.f64_slice_into(&mut self.m2)?;
        if self.mean.len() != dim || self.m2.len() != dim {
            return Err(format!(
                "Welford block has {} components, expected {dim}",
                self.mean.len()
            ));
        }
        Ok(())
    }
}

/// Streaming min / mean / max / last summary of the per-iteration bright
/// count (the paper's M) — what the experiment summary prints instead of
/// only the final `n_bright`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BrightStats {
    /// smallest observed bright count
    pub min: usize,
    /// largest observed bright count
    pub max: usize,
    /// most recently observed bright count
    pub last: usize,
    /// number of observations folded in
    pub count: usize,
    sum: u64,
}

impl Default for BrightStats {
    fn default() -> Self {
        BrightStats { min: usize::MAX, max: 0, last: 0, count: 0, sum: 0 }
    }
}

impl BrightStats {
    /// Fold one per-iteration bright count in.
    pub fn record(&mut self, b: usize) {
        self.min = self.min.min(b);
        self.max = self.max.max(b);
        self.last = b;
        self.sum += b as u64;
        self.count += 1;
    }

    /// Mean bright count (NaN before the first observation).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Serialize (bit-exact).
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.usize(self.min);
        w.usize(self.max);
        w.usize(self.last);
        w.usize(self.count);
        w.u64(self.sum);
    }

    /// Restore [`Self::save_state`] bytes.
    pub fn load_state(r: &mut ByteReader) -> Result<Self, String> {
        Ok(BrightStats {
            min: r.usize()?,
            max: r.usize()?,
            last: r.usize()?,
            count: r.usize()?,
            sum: r.u64()?,
        })
    }
}

/// The full O(dim) streaming engine: moments + batch-means ESS inputs +
/// split-R̂ half accumulators + bright-count summary. See the module docs
/// for the estimator definitions and the accuracy contract.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamingStats {
    dim: usize,
    rows_expected: usize,
    batch_size: usize,
    half_len: usize,
    rows_seen: usize,
    moments: WelfordVec,
    batch_sum: Vec<f64>,
    batch_fill: usize,
    batch_means: WelfordVec,
    first_half: WelfordVec,
    second_half: WelfordVec,
    /// per-iteration bright-count summary (FlyMC only; empty for regular).
    /// With online re-anchoring this covers the POST-re-anchor window; the
    /// pre-re-anchor counts go to [`StreamingStats::bright_pre`] so the two
    /// bound regimes are never conflated in one min/mean/max series.
    pub bright: BrightStats,
    /// bright counts observed BEFORE the re-anchor point (empty when
    /// re-anchoring is disabled: the observer then routes everything to
    /// [`StreamingStats::bright`], keeping legacy summaries identical)
    pub bright_pre: BrightStats,
    post_iters: usize,
    queries_sum: u64,
}

impl StreamingStats {
    /// Engine for a θ stream of `rows_expected` recorded `dim`-vectors
    /// (the post-burn-in, thinned trace cadence). The batch size is fixed
    /// at ⌈√rows_expected⌉ so the estimator is deterministic and
    /// checkpointable; the half split point is `rows_expected / 2`.
    pub fn new(dim: usize, rows_expected: usize) -> Self {
        let batch_size = (rows_expected as f64).sqrt().ceil().max(1.0) as usize;
        StreamingStats {
            dim,
            rows_expected,
            batch_size,
            half_len: rows_expected / 2,
            rows_seen: 0,
            moments: WelfordVec::new(dim),
            batch_sum: vec![0.0; dim],
            batch_fill: 0,
            batch_means: WelfordVec::new(dim),
            first_half: WelfordVec::new(dim),
            second_half: WelfordVec::new(dim),
            bright: BrightStats::default(),
            bright_pre: BrightStats::default(),
            post_iters: 0,
            queries_sum: 0,
        }
    }

    /// Number of θ rows folded in so far.
    pub fn rows(&self) -> usize {
        self.rows_seen
    }

    /// The fixed batch size B of the batch-means estimator.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Number of components per row.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Fold one recorded θ row in (O(dim), allocation-free).
    pub fn record_row(&mut self, row: &[f64]) {
        debug_assert_eq!(row.len(), self.dim);
        self.moments.update(row);
        if self.rows_seen < self.half_len {
            self.first_half.update(row);
        } else if self.rows_seen < 2 * self.half_len {
            self.second_half.update(row);
        }
        self.rows_seen += 1;
        for (s, &x) in self.batch_sum.iter_mut().zip(row) {
            *s += x;
        }
        self.batch_fill += 1;
        if self.batch_fill == self.batch_size {
            let b = self.batch_size as f64;
            for s in self.batch_sum.iter_mut() {
                *s /= b;
            }
            self.batch_means.update(&self.batch_sum);
            self.batch_sum.fill(0.0);
            self.batch_fill = 0;
        }
    }

    /// Fold one per-iteration bright count in.
    pub fn record_bright(&mut self, b: usize) {
        self.bright.record(b);
    }

    /// Fold one PRE-re-anchor bright count in (iterations before the bound
    /// restart; see [`StreamingStats::bright_pre`]).
    pub fn record_bright_pre(&mut self, b: usize) {
        self.bright_pre.record(b);
    }

    /// Fold one post-burn-in iteration's likelihood-query count in (O(1)
    /// memory — lets the Table-1 queries/iter column survive without the
    /// O(iters) per-iteration series).
    pub fn record_queries(&mut self, q: u64) {
        self.post_iters += 1;
        self.queries_sum += q;
    }

    /// Post-burn-in iterations folded via [`Self::record_queries`].
    pub fn post_iters(&self) -> usize {
        self.post_iters
    }

    /// Mean likelihood queries per post-burn-in iteration (NaN before the
    /// first observation).
    pub fn avg_queries(&self) -> f64 {
        if self.post_iters == 0 {
            f64::NAN
        } else {
            self.queries_sum as f64 / self.post_iters as f64
        }
    }

    /// Running mean of component `j`.
    pub fn mean(&self, j: usize) -> f64 {
        self.moments.mean(j)
    }

    /// Running unbiased variance of component `j`.
    pub fn var(&self, j: usize) -> f64 {
        self.moments.var(j)
    }

    /// Batch-means ESS of component `j`: with B the batch size, s² the
    /// sample variance and Var(μ_B) the variance across batch means,
    /// τ̂ = B·Var(μ_B)/s² and ESS = rows/τ̂, clamped to [1, rows]. NaN until
    /// at least two complete batches exist or when s² is degenerate.
    pub fn ess_batch_means(&self, j: usize) -> f64 {
        let s2 = self.moments.var(j);
        let bm = self.batch_means.var(j);
        if !(s2 > 0.0) || bm.is_nan() {
            return f64::NAN;
        }
        let tau = (self.batch_size as f64 * bm / s2).max(1e-12);
        (self.rows_seen as f64 / tau).clamp(1.0, self.rows_seen as f64)
    }

    /// Minimum batch-means ESS across components (the conservative figure
    /// the Table-1 trace estimator also reports).
    pub fn ess_batch_means_min(&self) -> f64 {
        let mut min = f64::INFINITY;
        for j in 0..self.dim {
            let e = self.ess_batch_means(j);
            if e.is_nan() {
                return f64::NAN;
            }
            min = min.min(e);
        }
        if min.is_infinite() {
            f64::NAN
        } else {
            min
        }
    }

    /// Single-chain split-R̂ (worst component) from the two half-window
    /// accumulators — the same Gelman–Rubin formula as
    /// [`crate::diagnostics::split_rhat_slices`] over m = 2 halves of
    /// length `rows_expected / 2`. NaN until both halves are complete.
    pub fn split_rhat_halves(&self) -> f64 {
        let n1 = self.first_half.count();
        let n2 = self.second_half.count();
        if n1 < 2 || n1 != n2 {
            return f64::NAN;
        }
        let n = n1 as f64;
        let mut worst = f64::NEG_INFINITY;
        for j in 0..self.dim {
            let (m1, m2) = (self.first_half.mean(j), self.second_half.mean(j));
            let w = 0.5 * (self.first_half.var(j) + self.second_half.var(j));
            if !(w > 0.0) {
                continue;
            }
            let grand = 0.5 * (m1 + m2);
            let b = n * ((m1 - grand) * (m1 - grand) + (m2 - grand) * (m2 - grand));
            let var_plus = (n - 1.0) / n * w + b / n;
            let r = (var_plus / w).sqrt();
            if r.is_finite() {
                worst = worst.max(r);
            }
        }
        if worst == f64::NEG_INFINITY {
            f64::NAN
        } else {
            worst
        }
    }

    /// Materialize the exportable summary (allocates; call once at the end
    /// of a run, never inside the sampling loop).
    pub fn summary(&self) -> StreamingSummary {
        StreamingSummary {
            rows: self.rows_seen,
            batch_size: self.batch_size,
            mean: (0..self.dim).map(|j| self.mean(j)).collect(),
            var: (0..self.dim).map(|j| self.var(j)).collect(),
            ess_bm_min: self.ess_batch_means_min(),
            split_rhat_halves: self.split_rhat_halves(),
            bright: self.bright,
            bright_pre: self.bright_pre,
            iters_post_burnin: self.post_iters,
            queries_post_burnin: self.queries_sum,
        }
    }

    /// Serialize the full accumulator state (bit-exact).
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.usize(self.dim);
        w.usize(self.rows_expected);
        w.usize(self.batch_size);
        w.usize(self.half_len);
        w.usize(self.rows_seen);
        self.moments.save_state(w);
        w.f64_slice(&self.batch_sum);
        w.usize(self.batch_fill);
        self.batch_means.save_state(w);
        self.first_half.save_state(w);
        self.second_half.save_state(w);
        self.bright.save_state(w);
        self.bright_pre.save_state(w);
        w.usize(self.post_iters);
        w.u64(self.queries_sum);
    }

    /// Restore [`Self::save_state`] bytes into an engine constructed with
    /// the same dimension (window geometry is taken from the checkpoint).
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let dim = r.usize()?;
        if dim != self.dim {
            return Err(format!("stats block has dim {dim}, expected {}", self.dim));
        }
        self.rows_expected = r.usize()?;
        self.batch_size = r.usize()?;
        self.half_len = r.usize()?;
        self.rows_seen = r.usize()?;
        if self.batch_size == 0 {
            return Err("zero batch size in stats block".to_string());
        }
        self.moments.load_state(r)?;
        r.f64_slice_into(&mut self.batch_sum)?;
        if self.batch_sum.len() != dim {
            return Err("batch accumulator shape mismatch".to_string());
        }
        self.batch_fill = r.usize()?;
        if self.batch_fill >= self.batch_size {
            return Err("batch fill exceeds batch size".to_string());
        }
        self.batch_means.load_state(r)?;
        self.first_half.load_state(r)?;
        self.second_half.load_state(r)?;
        self.bright = BrightStats::load_state(r)?;
        self.bright_pre = BrightStats::load_state(r)?;
        self.post_iters = r.usize()?;
        self.queries_sum = r.u64()?;
        Ok(())
    }
}

/// Exportable end-of-run summary of a [`StreamingStats`] engine — what
/// [`crate::engine::ChainResult`] carries for bounded-memory runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StreamingSummary {
    /// θ rows folded in
    pub rows: usize,
    /// fixed batch size B of the ESS estimator
    pub batch_size: usize,
    /// per-component streaming mean
    pub mean: Vec<f64>,
    /// per-component streaming unbiased variance
    pub var: Vec<f64>,
    /// minimum batch-means ESS across components (NaN if undefined)
    pub ess_bm_min: f64,
    /// single-chain split-R̂ over the two window halves (NaN if undefined)
    pub split_rhat_halves: f64,
    /// bright-count min/mean/max/last summary (count = 0 for regular MCMC);
    /// post-re-anchor window when online re-anchoring ran
    pub bright: BrightStats,
    /// pre-re-anchor bright-count summary (count = 0 unless a re-anchor
    /// split the run into two bound regimes)
    pub bright_pre: BrightStats,
    /// post-burn-in iterations folded in (drives the queries/iter average)
    pub iters_post_burnin: usize,
    /// total likelihood queries over those post-burn-in iterations
    pub queries_post_burnin: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diagnostics::{split_rhat_slices, TraceMatrix};
    use crate::util::math::{mean, variance};
    use crate::util::Rng;

    fn feed(rows: &[Vec<f64>]) -> StreamingStats {
        let dim = rows[0].len();
        let mut s = StreamingStats::new(dim, rows.len());
        for r in rows {
            s.record_row(r);
        }
        s
    }

    #[test]
    fn moments_match_batch_formulas() {
        let mut rng = Rng::new(1);
        let rows: Vec<Vec<f64>> = (0..5000)
            .map(|_| vec![rng.normal() * 2.0 + 1.0, rng.normal() * 0.5 - 3.0])
            .collect();
        let s = feed(&rows);
        let mut t = TraceMatrix::new(2);
        for r in &rows {
            t.push_row(r);
        }
        let mut col = Vec::new();
        for j in 0..2 {
            t.column_into(j, &mut col);
            let (bm, bv) = (mean(&col), variance(&col));
            assert!(
                (s.mean(j) - bm).abs() <= 1e-8 * (1.0 + bm.abs()),
                "mean[{j}] {} vs {bm}",
                s.mean(j)
            );
            assert!(
                (s.var(j) - bv).abs() <= 1e-8 * (1.0 + bv.abs()),
                "var[{j}] {} vs {bv}",
                s.var(j)
            );
        }
    }

    #[test]
    fn batch_means_ess_tracks_autocorrelation() {
        // iid chain: ESS ~ n; AR(1) rho=0.9: tau ~ 19, ESS ~ n/19
        let n = 40_000;
        let mut rng = Rng::new(2);
        let iid: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.normal()]).collect();
        let s = feed(&iid);
        let e = s.ess_batch_means(0);
        assert!(e > 0.5 * n as f64, "iid ESS {e}");
        let rho: f64 = 0.9;
        let mut x = 0.0;
        let ar: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                x = rho * x + (1.0 - rho * rho).sqrt() * rng.normal();
                vec![x]
            })
            .collect();
        let s = feed(&ar);
        let tau_est = n as f64 / s.ess_batch_means(0);
        let tau_true = (1.0 + rho) / (1.0 - rho); // 19
        assert!(
            (tau_est - tau_true).abs() / tau_true < 0.35,
            "tau {tau_est} vs {tau_true}"
        );
        assert_eq!(s.ess_batch_means_min(), s.ess_batch_means(0));
    }

    #[test]
    fn split_rhat_halves_matches_trace_estimator() {
        let n = 6000;
        let mut rng = Rng::new(3);
        // well-mixed: R-hat ~ 1; shifted halves: R-hat >> 1
        for shift in [0.0, 4.0] {
            let rows: Vec<Vec<f64>> = (0..n)
                .map(|i| {
                    let base = if i >= n / 2 { shift } else { 0.0 };
                    vec![rng.normal() + base]
                })
                .collect();
            let s = feed(&rows);
            let h = n / 2;
            let c1: Vec<f64> = rows[..h].iter().map(|r| r[0]).collect();
            let c2: Vec<f64> = rows[h..2 * h].iter().map(|r| r[0]).collect();
            // reference: the trace estimator over the two materialized
            // halves as separate "chains" of length h — split_rhat_slices
            // halves each again, so compare against the direct formula
            let m1 = mean(&c1);
            let m2 = mean(&c2);
            let v1 = variance(&c1);
            let v2 = variance(&c2);
            let g = 0.5 * (m1 + m2);
            let hf = h as f64;
            let b = hf * ((m1 - g).powi(2) + (m2 - g).powi(2));
            let w = 0.5 * (v1 + v2);
            let expect = (((hf - 1.0) / hf * w + b / hf) / w).sqrt();
            let got = s.split_rhat_halves();
            assert!(
                (got - expect).abs() <= 1e-6 * (1.0 + expect.abs()),
                "shift {shift}: {got} vs {expect}"
            );
            if shift > 0.0 {
                assert!(got > 1.5, "disjoint halves must inflate R-hat: {got}");
            } else {
                assert!((got - 1.0).abs() < 0.05, "well-mixed R-hat {got}");
            }
        }
        // sanity against the public slice estimator on a 2-chain layout:
        // feeding the halves as chains halved again still lands near 1
        let mut rng = Rng::new(4);
        let a: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let r = split_rhat_slices(&[&a, &b]);
        assert!((r - 1.0).abs() < 0.05, "slice-estimator sanity {r}");
    }

    #[test]
    fn bright_stats_pin_min_mean_max_last() {
        // pins the aggregation the experiment summary prints
        let mut b = BrightStats::default();
        assert_eq!(b.count, 0);
        assert!(b.mean().is_nan());
        for v in [7usize, 3, 11, 5] {
            b.record(v);
        }
        assert_eq!(b.min, 3);
        assert_eq!(b.max, 11);
        assert_eq!(b.last, 5);
        assert_eq!(b.count, 4);
        assert!((b.mean() - 6.5).abs() < 1e-12);
        let mut w = ByteWriter::new();
        b.save_state(&mut w);
        let bytes = w.into_bytes();
        let got = BrightStats::load_state(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(got, b);
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        // split the stream at an arbitrary point (mid-batch, mid-half);
        // save/restore must continue bit-identically
        let n = 3137;
        let cut = 1291;
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.normal(), rng.f64()]).collect();
        let mut full = StreamingStats::new(2, n);
        let mut partial = StreamingStats::new(2, n);
        for r in &rows[..cut] {
            full.record_row(r);
            partial.record_row(r);
        }
        for i in 0..cut {
            full.record_bright(i % 17);
            partial.record_bright(i % 17);
            full.record_queries((i % 23) as u64);
            partial.record_queries((i % 23) as u64);
        }
        let mut w = ByteWriter::new();
        partial.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut resumed = StreamingStats::new(2, n);
        let mut r = ByteReader::new(&bytes);
        resumed.load_state(&mut r).unwrap();
        r.finish().unwrap();
        for (i, row) in rows[cut..].iter().enumerate() {
            full.record_row(row);
            resumed.record_row(row);
            full.record_bright((cut + i) % 17);
            resumed.record_bright((cut + i) % 17);
            full.record_queries(((cut + i) % 23) as u64);
            resumed.record_queries(((cut + i) % 23) as u64);
        }
        assert_eq!(full, resumed);
        assert_eq!(full.post_iters(), n);
        assert!((full.avg_queries() - resumed.avg_queries()).abs() == 0.0);
        let (a, b) = (full.summary(), resumed.summary());
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.var, b.var);
        assert_eq!(a.ess_bm_min.to_bits(), b.ess_bm_min.to_bits());
        assert_eq!(a.split_rhat_halves.to_bits(), b.split_rhat_halves.to_bits());
        assert_eq!(a.bright, b.bright);

        // dim mismatch rejected
        let mut wrong = StreamingStats::new(3, n);
        assert!(wrong.load_state(&mut ByteReader::new(&bytes)).is_err());
    }
}
