//! Instrumentation counters.
//!
//! The paper reports computational cost in *likelihood evaluations per
//! iteration* — an implementation-independent unit. `Counters` is threaded
//! through every evaluator so both backends (CPU and XLA) account queries
//! identically: one "likelihood query" per datum whose `L_n` is computed,
//! one "bound query" per datum whose `B_n` is computed pointwise (the
//! collapsed product is O(1) in N and is tracked separately).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

/// Shared counters. `Send + Sync`: a chain's backend may shard one batch
/// across worker threads (`runtime::ParBackend`) and the multi-chain runner
/// spawns replicas on a pool, so the cells are relaxed atomics — each chain
/// still owns its own `Counters` and only totals are ever read, so relaxed
/// ordering preserves the exact snapshot/delta semantics the per-iteration
/// query accounting relies on (deltas are read between evaluations, never
/// concurrently with them).
#[derive(Clone, Debug, Default)]
pub struct Counters {
    inner: Arc<CounterCells>,
}

#[derive(Debug, Default)]
struct CounterCells {
    lik_queries: AtomicU64,
    bound_queries: AtomicU64,
    collapsed_bound_evals: AtomicU64,
    xla_executions: AtomicU64,
    padded_lanes: AtomicU64,
    data_cache_hits: AtomicU64,
    data_cache_misses: AtomicU64,
}

impl Counters {
    /// Fresh zeroed counters (clones share the same cells).
    pub fn new() -> Self {
        Self::default()
    }

    /// Count `n` likelihood queries.
    #[inline]
    pub fn add_lik(&self, n: u64) {
        self.inner.lik_queries.fetch_add(n, Relaxed);
    }
    /// Count `n` pointwise bound queries.
    #[inline]
    pub fn add_bound(&self, n: u64) {
        self.inner.bound_queries.fetch_add(n, Relaxed);
    }
    /// Count `n` collapsed bound-product evaluations (O(1) in N).
    #[inline]
    pub fn add_collapsed(&self, n: u64) {
        self.inner.collapsed_bound_evals.fetch_add(n, Relaxed);
    }
    /// Count `n` XLA executable launches.
    #[inline]
    pub fn add_xla_exec(&self, n: u64) {
        self.inner.xla_executions.fetch_add(n, Relaxed);
    }
    /// Count `n` padded (masked-out) batch lanes.
    #[inline]
    pub fn add_padded(&self, n: u64) {
        self.inner.padded_lanes.fetch_add(n, Relaxed);
    }
    /// Record feature-row block-cache hits and misses (drained from the
    /// backends' [`crate::data::store::RowCache`]s once per batch; both
    /// zero for dense stores). Deliberately NOT part of
    /// [`Counters::snapshot`]: hit patterns depend on cache topology (one
    /// cache serially vs one per worker group), so they are excluded from
    /// the cross-backend counter-equality contract.
    #[inline]
    pub fn add_data_cache(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.inner.data_cache_hits.fetch_add(hits, Relaxed);
        }
        if misses > 0 {
            self.inner.data_cache_misses.fetch_add(misses, Relaxed);
        }
    }

    /// Total likelihood queries so far.
    pub fn lik_queries(&self) -> u64 {
        self.inner.lik_queries.load(Relaxed)
    }
    /// Total pointwise bound queries so far.
    pub fn bound_queries(&self) -> u64 {
        self.inner.bound_queries.load(Relaxed)
    }
    /// Total collapsed bound-product evaluations so far.
    pub fn collapsed_bound_evals(&self) -> u64 {
        self.inner.collapsed_bound_evals.load(Relaxed)
    }
    /// Total XLA executable launches so far.
    pub fn xla_executions(&self) -> u64 {
        self.inner.xla_executions.load(Relaxed)
    }
    /// Total padded batch lanes so far.
    pub fn padded_lanes(&self) -> u64 {
        self.inner.padded_lanes.load(Relaxed)
    }
    /// Total feature-row block-cache hits so far.
    pub fn data_cache_hits(&self) -> u64 {
        self.inner.data_cache_hits.load(Relaxed)
    }
    /// Total feature-row block-cache misses so far.
    pub fn data_cache_misses(&self) -> u64 {
        self.inner.data_cache_misses.load(Relaxed)
    }

    /// Full totals of every cell (including the padded-lane and data-cache
    /// counters that [`Counters::snapshot`] deliberately excludes) — the
    /// checkpoint layer persists these so a resumed chain's final counter
    /// report matches the uninterrupted run's.
    pub fn totals(&self) -> CounterTotals {
        CounterTotals {
            lik_queries: self.lik_queries(),
            bound_queries: self.bound_queries(),
            collapsed_bound_evals: self.collapsed_bound_evals(),
            xla_executions: self.xla_executions(),
            padded_lanes: self.padded_lanes(),
            data_cache_hits: self.data_cache_hits(),
            data_cache_misses: self.data_cache_misses(),
        }
    }

    /// Overwrite every cell with checkpointed totals (shared across clones).
    /// Counterpart of [`Counters::totals`] on the resume path: construction
    /// work done while rebuilding a chain (e.g. the `init_z` full pass) is
    /// deliberately discarded — the restored totals already contain the
    /// original run's setup cost exactly once.
    pub fn restore_totals(&self, t: &CounterTotals) {
        self.inner.lik_queries.store(t.lik_queries, Relaxed);
        self.inner.bound_queries.store(t.bound_queries, Relaxed);
        self.inner
            .collapsed_bound_evals
            .store(t.collapsed_bound_evals, Relaxed);
        self.inner.xla_executions.store(t.xla_executions, Relaxed);
        self.inner.padded_lanes.store(t.padded_lanes, Relaxed);
        self.inner.data_cache_hits.store(t.data_cache_hits, Relaxed);
        self.inner
            .data_cache_misses
            .store(t.data_cache_misses, Relaxed);
    }

    /// Snapshot for per-iteration deltas.
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            lik_queries: self.lik_queries(),
            bound_queries: self.bound_queries(),
            collapsed_bound_evals: self.collapsed_bound_evals(),
            xla_executions: self.xla_executions(),
        }
    }

    /// Zero every counter (shared across clones).
    pub fn reset(&self) {
        self.inner.lik_queries.store(0, Relaxed);
        self.inner.bound_queries.store(0, Relaxed);
        self.inner.collapsed_bound_evals.store(0, Relaxed);
        self.inner.xla_executions.store(0, Relaxed);
        self.inner.padded_lanes.store(0, Relaxed);
        self.inner.data_cache_hits.store(0, Relaxed);
        self.inner.data_cache_misses.store(0, Relaxed);
    }
}

/// Transport-layer tallies for the distributed backend: wire traffic and
/// the retry/reconnect failure path (`runtime::DistBackend`).
///
/// Deliberately a separate struct, **outside** both [`CounterTotals`]
/// (whose `.fckpt` byte layout is a fixed 7 × u64 contract shared by every
/// backend family) and [`CounterSnapshot`] (the cross-backend
/// counter-equality contract): wire traffic is execution topology, not
/// statistical cost — a dist chain must report the *same* likelihood-query
/// counters as the serial chain while these cells differ per worker count.
/// Clones share cells, like [`Counters`].
#[derive(Clone, Debug, Default)]
pub struct WireStats {
    inner: Arc<WireCells>,
}

#[derive(Debug, Default)]
struct WireCells {
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    requests: AtomicU64,
    retries: AtomicU64,
    reconnects: AtomicU64,
}

impl WireStats {
    /// Fresh zeroed stats (clones share the same cells).
    pub fn new() -> Self {
        Self::default()
    }
    /// Count `n` bytes put on the wire (frame overhead included).
    #[inline]
    pub fn add_sent(&self, n: u64) {
        self.inner.bytes_sent.fetch_add(n, Relaxed);
    }
    /// Count `n` bytes taken off the wire (frame overhead included).
    #[inline]
    pub fn add_received(&self, n: u64) {
        self.inner.bytes_received.fetch_add(n, Relaxed);
    }
    /// Count one coordinator→worker request (retries of the same request
    /// count again here but never in the likelihood-query counters).
    #[inline]
    pub fn add_request(&self) {
        self.inner.requests.fetch_add(1, Relaxed);
    }
    /// Count one retry attempt after a transport failure.
    #[inline]
    pub fn add_retry(&self) {
        self.inner.retries.fetch_add(1, Relaxed);
    }
    /// Count one reconnect (fresh TCP connection + re-handshake).
    #[inline]
    pub fn add_reconnect(&self) {
        self.inner.reconnects.fetch_add(1, Relaxed);
    }
    /// Total bytes sent so far.
    pub fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent.load(Relaxed)
    }
    /// Total bytes received so far.
    pub fn bytes_received(&self) -> u64 {
        self.inner.bytes_received.load(Relaxed)
    }
    /// Total requests sent so far (including retried sends).
    pub fn requests(&self) -> u64 {
        self.inner.requests.load(Relaxed)
    }
    /// Total retry attempts so far.
    pub fn retries(&self) -> u64 {
        self.inner.retries.load(Relaxed)
    }
    /// Total reconnects so far.
    pub fn reconnects(&self) -> u64 {
        self.inner.reconnects.load(Relaxed)
    }
}

/// Complete point-in-time totals of every counter cell — the checkpointable
/// superset of [`CounterSnapshot`] (see [`Counters::totals`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterTotals {
    /// likelihood queries
    pub lik_queries: u64,
    /// pointwise bound queries
    pub bound_queries: u64,
    /// collapsed bound-product evaluations
    pub collapsed_bound_evals: u64,
    /// XLA executable launches
    pub xla_executions: u64,
    /// padded (masked-out) batch lanes
    pub padded_lanes: u64,
    /// feature-row block-cache hits (best-effort; cache-topology-dependent)
    pub data_cache_hits: u64,
    /// feature-row block-cache misses (best-effort; cache-topology-dependent)
    pub data_cache_misses: u64,
}

impl CounterTotals {
    /// Serialize (fixed 7 × u64 layout).
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.u64(self.lik_queries);
        w.u64(self.bound_queries);
        w.u64(self.collapsed_bound_evals);
        w.u64(self.xla_executions);
        w.u64(self.padded_lanes);
        w.u64(self.data_cache_hits);
        w.u64(self.data_cache_misses);
    }

    /// Deserialize the [`Self::save_state`] layout.
    pub fn load_state(r: &mut crate::util::codec::ByteReader) -> Result<Self, String> {
        Ok(CounterTotals {
            lik_queries: r.u64()?,
            bound_queries: r.u64()?,
            collapsed_bound_evals: r.u64()?,
            xla_executions: r.u64()?,
            padded_lanes: r.u64()?,
            data_cache_hits: r.u64()?,
            data_cache_misses: r.u64()?,
        })
    }
}

/// Point-in-time copy of the counters, for per-iteration deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// likelihood queries at snapshot time
    pub lik_queries: u64,
    /// pointwise bound queries at snapshot time
    pub bound_queries: u64,
    /// collapsed bound-product evaluations at snapshot time
    pub collapsed_bound_evals: u64,
    /// XLA executable launches at snapshot time
    pub xla_executions: u64,
}

impl CounterSnapshot {
    /// Counter increments between `self` and the `later` snapshot.
    pub fn delta(&self, later: &CounterSnapshot) -> CounterSnapshot {
        CounterSnapshot {
            lik_queries: later.lik_queries - self.lik_queries,
            bound_queries: later.bound_queries - self.bound_queries,
            collapsed_bound_evals: later.collapsed_bound_evals - self.collapsed_bound_evals,
            xla_executions: later.xla_executions - self.xla_executions,
        }
    }
}

/// Simple streaming histogram for per-iteration quantities (bright counts,
/// queries). Fixed-width bins; used by the bench reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// inclusive lower edge of the binned range
    pub lo: f64,
    /// exclusive upper edge of the binned range
    pub hi: f64,
    /// fixed-width bin counts over [lo, hi)
    pub bins: Vec<u64>,
    /// samples below `lo`
    pub underflow: u64,
    /// samples at or above `hi`
    pub overflow: u64,
    /// total samples recorded (including under/overflow)
    pub count: u64,
    /// running sum of samples
    pub sum: f64,
    /// running sum of squared samples
    pub sum_sq: f64,
}

impl Histogram {
    /// Histogram with `nbins` equal bins over [lo, hi).
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
        }
    }

    /// Record one sample.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sum_sq += x * x;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nbins = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * nbins as f64) as usize;
            self.bins[b.min(nbins - 1)] += 1;
        }
    }

    /// Mean of all recorded samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Population standard deviation (NaN with < 2 samples).
    pub fn std(&self) -> f64 {
        if self.count < 2 {
            return f64::NAN;
        }
        let m = self.mean();
        ((self.sum_sq / self.count as f64 - m * m).max(0.0)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = Counters::new();
        c.add_lik(10);
        c.add_bound(3);
        let snap = c.snapshot();
        c.add_lik(5);
        c.add_xla_exec(1);
        let d = snap.delta(&c.snapshot());
        assert_eq!(d.lik_queries, 5);
        assert_eq!(d.bound_queries, 0);
        assert_eq!(d.xla_executions, 1);
        assert_eq!(c.lik_queries(), 15);
        c.reset();
        assert_eq!(c.lik_queries(), 0);
    }

    #[test]
    fn data_cache_counters_accumulate_outside_snapshots() {
        let c = Counters::new();
        c.add_data_cache(10, 3);
        c.add_data_cache(0, 0); // no-op fast path
        assert_eq!(c.data_cache_hits(), 10);
        assert_eq!(c.data_cache_misses(), 3);
        // cache stats are deliberately not part of the snapshot equality
        // contract (hit patterns are cache-topology-dependent)
        let a = c.snapshot();
        c.add_data_cache(5, 5);
        assert_eq!(a, c.snapshot());
        c.reset();
        assert_eq!(c.data_cache_hits(), 0);
        assert_eq!(c.data_cache_misses(), 0);
    }

    #[test]
    fn totals_roundtrip_restores_every_cell() {
        let c = Counters::new();
        c.add_lik(10);
        c.add_bound(4);
        c.add_collapsed(3);
        c.add_xla_exec(2);
        c.add_padded(1);
        c.add_data_cache(7, 5);
        let t = c.totals();
        let mut w = crate::util::codec::ByteWriter::new();
        t.save_state(&mut w);
        let bytes = w.into_bytes();
        let got =
            CounterTotals::load_state(&mut crate::util::codec::ByteReader::new(&bytes)).unwrap();
        assert_eq!(got, t);
        let d = Counters::new();
        d.add_lik(999); // construction noise, overwritten by restore
        d.restore_totals(&got);
        assert_eq!(d.totals(), t);
        assert_eq!(d.snapshot(), c.snapshot());
    }

    #[test]
    fn counters_are_shared_clones() {
        let a = Counters::new();
        let b = a.clone();
        b.add_lik(7);
        assert_eq!(a.lik_queries(), 7);
    }

    #[test]
    fn counters_accumulate_across_threads() {
        let c = Counters::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = c.clone();
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.add_lik(1);
                        c.add_bound(2);
                    }
                });
            }
        });
        assert_eq!(c.lik_queries(), 4000);
        assert_eq!(c.bound_queries(), 8000);
    }

    #[test]
    fn wire_stats_are_shared_and_outside_the_counter_contract() {
        let w = WireStats::new();
        let w2 = w.clone();
        w2.add_sent(100);
        w2.add_received(240);
        w2.add_request();
        w2.add_request();
        w2.add_retry();
        w2.add_reconnect();
        assert_eq!(w.bytes_sent(), 100);
        assert_eq!(w.bytes_received(), 240);
        assert_eq!(w.requests(), 2);
        assert_eq!(w.retries(), 1);
        assert_eq!(w.reconnects(), 1);
        // wire traffic must not perturb the query-counter equality contract
        let c = Counters::new();
        let snap = c.snapshot();
        let totals = c.totals();
        w.add_sent(1);
        assert_eq!(snap, c.snapshot());
        assert_eq!(totals, c.totals());
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(f64::from(i) + 0.5);
        }
        h.record(-1.0);
        h.record(42.0);
        assert_eq!(h.count, 12);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.bins.iter().sum::<u64>(), 10);
        // sum = (0.5+...+9.5) + (-1) + 42 = 50 + 41 = 91
        assert!((h.mean() - 91.0 / 12.0).abs() < 1e-12);
    }
}
