//! Batched logistic-regression kernels (Jaakkola–Jordan bound).
//!
//! Tile-at-a-time versions of every [`crate::models::LogisticJJ`]
//! evaluation: gather a `W`-lane feature tile, one [`LanePath::dot_lanes`]
//! for the margins `s = t_n θᵀx_n`, shared scalar transcendentals per
//! lane, and [`LanePath::acc_grad_tile`] for gradient folds. Values are
//! bit-identical to the per-datum formulas (same canonical dot tree);
//! gradients fold through [`super::tree8`].

use super::{tree8, LanePath, W};
use crate::models::logistic::{jj_coeffs, LogisticJJ};
use crate::models::{bright_coeff, EvalScratch};
use crate::util::math::{log_sigmoid, sigmoid};

/// `ll[i] = log L_{idx[i]}(θ)` for the whole batch.
// lint: zero-alloc
pub fn log_lik_batch<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            ll[base + l] = log_sigmoid(m.data.t[n as usize] * s[l]);
        }
        base += chunk.len();
    }
}

/// `(ll[i], lb[i]) = (log L, clamped log B)` for the whole batch.
// lint: zero-alloc
pub fn log_both_batch<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let llv = log_sigmoid(sv);
            let (a, b, c) = jj_coeffs(m.xi[n]);
            ll[base + l] = llv;
            lb[base + l] = (a * sv * sv + b * sv + c).min(llv);
        }
        base += chunk.len();
    }
}

/// Fused batch `log_both` + pseudo-likelihood gradient accumulation:
/// fills `ll`/`lb` and folds each tile's bright-point coefficients into
/// `grad` through the canonical reduction tree.
// lint: zero-alloc
pub fn pseudo_grad_batch<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut coeff = [0.0; W]; // dead lanes must contribute exact +0.0 products
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let llv = log_sigmoid(sv);
            let (a, b, c) = jj_coeffs(m.xi[n]);
            let lbv = (a * sv * sv + b * sv + c).min(llv);
            let dll = sigmoid(-sv);
            let dlb = 2.0 * a * sv + b;
            coeff[l] = bright_coeff(dll, dlb, lbv - llv) * m.data.t[n];
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        P::acc_grad_tile(&coeff, tile, grad);
        base += chunk.len();
    }
}

/// Fused batch `log_lik` + likelihood-gradient accumulation.
// lint: zero-alloc
pub fn log_lik_grad_batch<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut coeff = [0.0; W];
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            ll[base + l] = log_sigmoid(sv);
            coeff[l] = sigmoid(-sv) * m.data.t[n];
        }
        P::acc_grad_tile(&coeff, tile, grad);
        base += chunk.len();
    }
}

/// Batch `log_both` + per-datum pseudo-gradient **product rows**: fills
/// `ll`/`lb` exactly as [`pseudo_grad_batch`] does, but instead of folding
/// each tile into `grad` it writes the raw single-multiply products
/// `coeff_i · x_i[j]` into `rows_out[i * d + j]`. Coefficients come off
/// the same gather/dot/coefficient pipeline, so every stored product has
/// exactly the bits [`LanePath::acc_grad_tile`] would multiply — the shard
/// workers' half of the distributed gradient contract; the coordinator's
/// [`crate::kernels::fold_grad_rows`] replays the canonical fold over
/// them (DESIGN.md §Distribution).
// lint: zero-alloc
pub fn pseudo_grad_rows<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    debug_assert_eq!(rows_out.len(), idx.len() * d);
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let llv = log_sigmoid(sv);
            let (a, b, c) = jj_coeffs(m.xi[n]);
            let lbv = (a * sv * sv + b * sv + c).min(llv);
            let dll = sigmoid(-sv);
            let dlb = 2.0 * a * sv + b;
            let coeff = bright_coeff(dll, dlb, lbv - llv) * m.data.t[n];
            let row_out = &mut rows_out[(base + l) * d..(base + l + 1) * d];
            for (j, o) in row_out.iter_mut().enumerate() {
                *o = coeff * tile[j * W + l];
            }
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + per-datum likelihood-gradient **product rows** (the
/// `eval_lik_grad` companion of [`pseudo_grad_rows`]; same contract).
// lint: zero-alloc
pub fn log_lik_grad_rows<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    debug_assert_eq!(rows_out.len(), idx.len() * d);
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let coeff = sigmoid(-sv) * m.data.t[n];
            let row_out = &mut rows_out[(base + l) * d..(base + l + 1) * d];
            for (j, o) in row_out.iter_mut().enumerate() {
                *o = coeff * tile[j * W + l];
            }
            ll[base + l] = log_sigmoid(sv);
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + likelihood gradient with **per-datum accumulation
/// order**: values come off the shared tile through the canonical
/// [`LanePath::dot_lanes`] contract (bit-identical to per-datum dots), but
/// the gradient is accumulated lane-by-lane in index order — the exact op
/// sequence of repeated per-datum `log_lik_grad_acc` calls. The `+ 0.0`
/// reproduces the single-live-lane `tree8` fold's `-0.0` canonicalization
/// bit-for-bit (see `single_live_lane_reproduces_axpy_bits`). This is the
/// anchor-invariant entry point `map_estimate` batches through.
// lint: zero-alloc
pub fn log_lik_grad_ordered<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let c = sigmoid(-sv) * m.data.t[n];
            for (j, g) in grad.iter_mut().enumerate() {
                *g += c * tile[j * W + l] + 0.0;
            }
            ll[base + l] = log_sigmoid(sv);
        }
        base += chunk.len();
    }
}

/// `Σ_i log B_{idx[i]}(θ)` (clamped bounds, as in `log_both`), each tile
/// folded through [`tree8`] and tiles summed in batch order.
// lint: zero-alloc
pub fn log_bound_product_batch<P: LanePath>(
    m: &LogisticJJ,
    theta: &[f64],
    idx: &[u32],
    scratch: &mut EvalScratch,
) -> f64 {
    let d = theta.len();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut total = 0.0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut lanes = [0.0; W];
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let sv = m.data.t[n] * s[l];
            let llv = log_sigmoid(sv);
            let (a, b, c) = jj_coeffs(m.xi[n]);
            lanes[l] = (a * sv * sv + b * sv + c).min(llv);
        }
        total += tree8(&lanes);
    }
    total
}
