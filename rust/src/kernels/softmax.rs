//! Batched softmax-classification kernels (Böhning bound).
//!
//! Tile-at-a-time versions of every [`crate::models::SoftmaxBohning`]
//! evaluation: one [`LanePath::dot_lanes`] per class per tile fills the
//! lane-major logit buffer (`scratch.lane_eta[l * K + kk]`), so each
//! lane's η vector is a contiguous slice fed through exactly the same
//! scalar `logsumexp`/bound code as the per-datum path; gradients fold
//! class-by-class through [`LanePath::acc_grad_tile`] into the `[K, D]`
//! row-major `grad`.

use super::{tree8, LanePath, W};
use crate::models::softmax::SoftmaxBohning;
use crate::models::EvalScratch;
use crate::util::math::logsumexp;

/// Fill the lane-major logit buffer for one gathered tile:
/// `lane_eta[l * k + kk] = dot(θ_kk, lane l)` via the canonical dot tree.
// lint: zero-alloc
#[inline]
fn logits_tile<P: LanePath>(theta: &[f64], k: usize, tile: &[f64], lane_eta: &mut [f64]) {
    let d = theta.len() / k;
    let mut s = [0.0; W];
    for kk in 0..k {
        P::dot_lanes(&theta[kk * d..(kk + 1) * d], tile, &mut s);
        for l in 0..W {
            lane_eta[l * k + kk] = s[l];
        }
    }
}

/// `ll[i] = log L_{idx[i]}(θ)` for the whole batch.
// lint: zero-alloc
pub fn log_lik_batch<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let eta = &lane_eta[l * k..(l + 1) * k];
            ll[base + l] = eta[m.data.labels[n as usize]] - logsumexp(eta);
        }
        base += chunk.len();
    }
}

/// `(ll[i], lb[i]) = (log L, clamped log B)` for the whole batch.
// lint: zero-alloc
pub fn log_both_batch<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let eta = &lane_eta[l * k..(l + 1) * k];
            let llv = eta[m.data.labels[n]] - logsumexp(eta);
            ll[base + l] = llv;
            lb[base + l] = m.log_bound_and_deta(eta, n, None).min(llv);
        }
        base += chunk.len();
    }
}

/// Fused batch `log_both` + pseudo-likelihood gradient accumulation into
/// the `[K, D]` row-major `grad`, one class-segment tree fold per tile.
// lint: zero-alloc
pub fn pseudo_grad_batch<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, lane_dlb, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let lane_dlb = &mut lane_dlb[..k * W];
    let mut lse = [0.0; W];
    let mut ed = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let eta = &lane_eta[l * k..(l + 1) * k];
            let lse_l = logsumexp(eta);
            let llv = eta[m.data.labels[n]] - lse_l;
            let lbv = m
                .log_bound_and_deta(eta, n, Some(&mut lane_dlb[l * k..(l + 1) * k]))
                .min(llv);
            lse[l] = lse_l;
            ed[l] = (lbv - llv).min(-1e-12).exp();
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        for kk in 0..k {
            let mut coeff = [0.0; W]; // dead lanes must contribute exact +0.0 products
            for (l, &n) in chunk.iter().enumerate() {
                let n = n as usize;
                let dll = (if kk == m.data.labels[n] { 1.0 } else { 0.0 })
                    - (lane_eta[l * k + kk] - lse[l]).exp();
                let dlb = lane_dlb[l * k + kk];
                coeff[l] = (dll - ed[l] * dlb) / (1.0 - ed[l]) - dlb;
            }
            P::acc_grad_tile(&coeff, tile, &mut grad[kk * d..(kk + 1) * d]);
        }
        base += chunk.len();
    }
}

/// Fused batch `log_lik` + likelihood-gradient accumulation into the
/// `[K, D]` row-major `grad`.
// lint: zero-alloc
pub fn log_lik_grad_batch<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut lse = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let eta = &lane_eta[l * k..(l + 1) * k];
            let lse_l = logsumexp(eta);
            lse[l] = lse_l;
            ll[base + l] = eta[m.data.labels[n as usize]] - lse_l;
        }
        for kk in 0..k {
            let mut coeff = [0.0; W];
            for (l, &n) in chunk.iter().enumerate() {
                let n = n as usize;
                coeff[l] = (if kk == m.data.labels[n] { 1.0 } else { 0.0 })
                    - (lane_eta[l * k + kk] - lse[l]).exp();
            }
            P::acc_grad_tile(&coeff, tile, &mut grad[kk * d..(kk + 1) * d]);
        }
        base += chunk.len();
    }
}

/// Batch `log_both` + per-datum pseudo-gradient **product rows** into
/// `rows_out[i * (K·D) + kk·d + j] = coeff_{kk,i} · x_i[j]` — the kernels'
/// per-tile class segments flatten to exactly this `kk`-major, `j`-minor
/// order, which is the flat component order [`pseudo_grad_batch`]'s
/// `acc_grad_tile` calls write the `[K, D]` gradient in. Coefficients come
/// off the same gather/logits/bound pipeline, so each stored product has
/// the bits the fold would multiply; the coordinator's
/// [`crate::kernels::fold_grad_rows`] replays the canonical reduction
/// (DESIGN.md §Distribution).
// lint: zero-alloc
pub fn pseudo_grad_rows<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let dim = k * d;
    debug_assert_eq!(rows_out.len(), idx.len() * dim);
    let EvalScratch { rows, tile, lane_eta, lane_dlb, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let lane_dlb = &mut lane_dlb[..k * W];
    let mut lse = [0.0; W];
    let mut ed = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let eta = &lane_eta[l * k..(l + 1) * k];
            let lse_l = logsumexp(eta);
            let llv = eta[m.data.labels[n]] - lse_l;
            let lbv = m
                .log_bound_and_deta(eta, n, Some(&mut lane_dlb[l * k..(l + 1) * k]))
                .min(llv);
            lse[l] = lse_l;
            ed[l] = (lbv - llv).min(-1e-12).exp();
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        for kk in 0..k {
            for (l, &n) in chunk.iter().enumerate() {
                let n = n as usize;
                let dll = (if kk == m.data.labels[n] { 1.0 } else { 0.0 })
                    - (lane_eta[l * k + kk] - lse[l]).exp();
                let dlb = lane_dlb[l * k + kk];
                let coeff = (dll - ed[l] * dlb) / (1.0 - ed[l]) - dlb;
                let seg = &mut rows_out
                    [(base + l) * dim + kk * d..(base + l) * dim + (kk + 1) * d];
                for (j, o) in seg.iter_mut().enumerate() {
                    *o = coeff * tile[j * W + l];
                }
            }
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + per-datum likelihood-gradient **product rows** (the
/// `eval_lik_grad` companion of [`pseudo_grad_rows`]; same contract and
/// `kk`-major, `j`-minor component order).
// lint: zero-alloc
pub fn log_lik_grad_rows<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let dim = k * d;
    debug_assert_eq!(rows_out.len(), idx.len() * dim);
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut lse = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let eta = &lane_eta[l * k..(l + 1) * k];
            let lse_l = logsumexp(eta);
            lse[l] = lse_l;
            ll[base + l] = eta[m.data.labels[n as usize]] - lse_l;
        }
        for kk in 0..k {
            for (l, &n) in chunk.iter().enumerate() {
                let n = n as usize;
                let coeff = (if kk == m.data.labels[n] { 1.0 } else { 0.0 })
                    - (lane_eta[l * k + kk] - lse[l]).exp();
                let seg = &mut rows_out
                    [(base + l) * dim + kk * d..(base + l) * dim + (kk + 1) * d];
                for (j, o) in seg.iter_mut().enumerate() {
                    *o = coeff * tile[j * W + l];
                }
            }
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + likelihood gradient with **per-datum accumulation
/// order**: lanes are drained in index order, and within each datum the
/// classes are walked class-outer exactly as the per-datum
/// `log_lik_grad_acc` (batch-of-1) does — so `grad` and `ll` are
/// bit-identical to the per-datum reference loop (see the logistic
/// kernel's `log_lik_grad_ordered` for the `+ 0.0` canonicalization
/// argument).
// lint: zero-alloc
pub fn log_lik_grad_ordered<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let lse_l = logsumexp(&lane_eta[l * k..(l + 1) * k]);
            for kk in 0..k {
                let c = (if kk == m.data.labels[n] { 1.0 } else { 0.0 })
                    - (lane_eta[l * k + kk] - lse_l).exp();
                let seg = &mut grad[kk * d..(kk + 1) * d];
                for (j, g) in seg.iter_mut().enumerate() {
                    *g += c * tile[j * W + l] + 0.0;
                }
            }
            ll[base + l] = lane_eta[l * k + m.data.labels[n]] - lse_l;
        }
        base += chunk.len();
    }
}

/// `Σ_i log B_{idx[i]}(θ)` (clamped bounds, as in `log_both`), each tile
/// folded through [`tree8`] and tiles summed in batch order.
// lint: zero-alloc
pub fn log_bound_product_batch<P: LanePath>(
    m: &SoftmaxBohning,
    theta: &[f64],
    idx: &[u32],
    scratch: &mut EvalScratch,
) -> f64 {
    let k = m.k;
    let d = m.data.d();
    let EvalScratch { rows, tile, lane_eta, .. } = scratch;
    let tile = &mut tile[..d * W];
    let lane_eta = &mut lane_eta[..k * W];
    let mut total = 0.0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        logits_tile::<P>(theta, k, tile, lane_eta);
        let mut lanes = [0.0; W];
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let eta = &lane_eta[l * k..(l + 1) * k];
            let llv = eta[m.data.labels[n]] - logsumexp(eta);
            lanes[l] = m.log_bound_and_deta(eta, n, None).min(llv);
        }
        total += tree8(&lanes);
    }
    total
}
