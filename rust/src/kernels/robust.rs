//! Batched robust (student-t) regression kernels (tangent Gaussian bound).
//!
//! Tile-at-a-time versions of every [`crate::models::RobustT`] evaluation:
//! one [`LanePath::dot_lanes`] per tile for the predictions `θᵀx_n`,
//! shared scalar per-lane residual/tangent math, gradient folds through
//! [`LanePath::acc_grad_tile`]. The per-datum code negates the bright
//! coefficient before its `axpy` (`dr/dθ = -x`); here the negation folds
//! into the lane coefficient, which is exact.

use super::{tree8, LanePath, W};
use crate::models::robust::RobustT;
use crate::models::{bright_coeff, EvalScratch};

/// `ll[i] = log L_{idx[i]}(θ)` for the whole batch.
// lint: zero-alloc
pub fn log_lik_batch<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let r = m.data.y[n as usize] - s[l];
            ll[base + l] = m.logc - (m.nu + 1.0) / 2.0 * (r * r / c2).ln_1p();
        }
        base += chunk.len();
    }
}

/// `(ll[i], lb[i]) = (log L, clamped log B)` for the whole batch.
// lint: zero-alloc
pub fn log_both_batch<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let u = r * r;
            let llv = m.logc - (m.nu + 1.0) / 2.0 * (u / c2).ln_1p();
            let (f0, fp0) = m.tangent(m.u0[n]);
            ll[base + l] = llv;
            lb[base + l] = (f0 + fp0 * (u - m.u0[n])).min(llv);
        }
        base += chunk.len();
    }
}

/// Fused batch `log_both` + pseudo-likelihood gradient accumulation.
// lint: zero-alloc
pub fn pseudo_grad_batch<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut coeff = [0.0; W]; // dead lanes must contribute exact +0.0 products
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let u = r * r;
            let llv = m.logc - (m.nu + 1.0) / 2.0 * (u / c2).ln_1p();
            let (f0, fp0) = m.tangent(m.u0[n]);
            let lbv = (f0 + fp0 * (u - m.u0[n])).min(llv);
            let dll = -(m.nu + 1.0) * r / (c2 + u);
            let dlb = 2.0 * fp0 * r;
            coeff[l] = -bright_coeff(dll, dlb, lbv - llv);
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        P::acc_grad_tile(&coeff, tile, grad);
        base += chunk.len();
    }
}

/// Fused batch `log_lik` + likelihood-gradient accumulation.
// lint: zero-alloc
pub fn log_lik_grad_batch<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut coeff = [0.0; W];
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            ll[base + l] = m.logc - (m.nu + 1.0) / 2.0 * (r * r / c2).ln_1p();
            coeff[l] = (m.nu + 1.0) * r / (c2 + r * r);
        }
        P::acc_grad_tile(&coeff, tile, grad);
        base += chunk.len();
    }
}

/// Batch `log_both` + per-datum pseudo-gradient **product rows** (see the
/// logistic kernel's `pseudo_grad_rows` for the distributed-gradient
/// contract). The per-lane negation of the bright coefficient folds into
/// the stored coefficient exactly as in [`pseudo_grad_batch`], so each
/// product has the bits [`LanePath::acc_grad_tile`] would multiply.
// lint: zero-alloc
pub fn pseudo_grad_rows<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    lb: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    debug_assert_eq!(lb.len(), idx.len());
    let d = theta.len();
    debug_assert_eq!(rows_out.len(), idx.len() * d);
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let u = r * r;
            let llv = m.logc - (m.nu + 1.0) / 2.0 * (u / c2).ln_1p();
            let (f0, fp0) = m.tangent(m.u0[n]);
            let lbv = (f0 + fp0 * (u - m.u0[n])).min(llv);
            let dll = -(m.nu + 1.0) * r / (c2 + u);
            let dlb = 2.0 * fp0 * r;
            let coeff = -bright_coeff(dll, dlb, lbv - llv);
            let row_out = &mut rows_out[(base + l) * d..(base + l + 1) * d];
            for (j, o) in row_out.iter_mut().enumerate() {
                *o = coeff * tile[j * W + l];
            }
            ll[base + l] = llv;
            lb[base + l] = lbv;
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + per-datum likelihood-gradient **product rows** (the
/// `eval_lik_grad` companion of [`pseudo_grad_rows`]; same contract).
// lint: zero-alloc
pub fn log_lik_grad_rows<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    rows_out: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    debug_assert_eq!(rows_out.len(), idx.len() * d);
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let coeff = (m.nu + 1.0) * r / (c2 + r * r);
            let row_out = &mut rows_out[(base + l) * d..(base + l + 1) * d];
            for (j, o) in row_out.iter_mut().enumerate() {
                *o = coeff * tile[j * W + l];
            }
            ll[base + l] = m.logc - (m.nu + 1.0) / 2.0 * (r * r / c2).ln_1p();
        }
        base += chunk.len();
    }
}

/// Batch `log_lik` + likelihood gradient with **per-datum accumulation
/// order** — bit-identical to repeated per-datum `log_lik_grad_acc` /
/// `log_lik` calls over `idx` in order (see the logistic kernel's
/// `log_lik_grad_ordered` for the contract and the `+ 0.0`
/// canonicalization argument).
// lint: zero-alloc
pub fn log_lik_grad_ordered<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    ll: &mut [f64],
    grad: &mut [f64],
    scratch: &mut EvalScratch,
) {
    debug_assert_eq!(ll.len(), idx.len());
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut base = 0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let c = (m.nu + 1.0) * r / (c2 + r * r);
            for (j, g) in grad.iter_mut().enumerate() {
                *g += c * tile[j * W + l] + 0.0;
            }
            ll[base + l] = m.logc - (m.nu + 1.0) / 2.0 * (r * r / c2).ln_1p();
        }
        base += chunk.len();
    }
}

/// `Σ_i log B_{idx[i]}(θ)` (clamped bounds, as in `log_both`), each tile
/// folded through [`tree8`] and tiles summed in batch order.
// lint: zero-alloc
pub fn log_bound_product_batch<P: LanePath>(
    m: &RobustT,
    theta: &[f64],
    idx: &[u32],
    scratch: &mut EvalScratch,
) -> f64 {
    let d = theta.len();
    let c2 = m.c2();
    let EvalScratch { rows, tile, .. } = scratch;
    let tile = &mut tile[..d * W];
    let mut s = [0.0; W];
    let mut total = 0.0;
    for chunk in idx.chunks(W) {
        m.data.x.gather_tile(chunk, rows, tile);
        P::dot_lanes(theta, tile, &mut s);
        let mut lanes = [0.0; W];
        for (l, &n) in chunk.iter().enumerate() {
            let n = n as usize;
            let r = m.data.y[n] - s[l];
            let u = r * r;
            let llv = m.logc - (m.nu + 1.0) / 2.0 * (u / c2).ln_1p();
            let (f0, fp0) = m.tangent(m.u0[n]);
            lanes[l] = (f0 + fp0 * (u - m.u0[n])).min(llv);
        }
        total += tree8(&lanes);
    }
    total
}
