//! Batched structure-of-arrays likelihood kernels (DESIGN.md §Kernels).
//!
//! This module is the single home of the evaluation stack's inner loops.
//! Everything the models evaluate per datum — logistic / softmax / robust
//! likelihoods, bounds, and gradients — is expressed here as a *batch*
//! kernel over fixed-width lane tiles, in two interchangeable
//! implementations selected by the [`LanePath`] type parameter:
//!
//! * [`ScalarPath`] — lane-outer scalar reference loops, one datum at a
//!   time, strided reads from the tile;
//! * [`FastPath`] — feature-outer loops over contiguous `W`-wide tile
//!   columns with fixed-size `[f64; W]` accumulator arrays, the shape LLVM
//!   autovectorizes (no `unsafe`, no intrinsics; `RUSTFLAGS=-C
//!   target-cpu=native` in CI exercises the widest encodings).
//!
//! ## The bit-exactness contract
//!
//! Both paths produce **identical bits** for every output, because both
//! follow the same two canonical association trees and rustc never
//! contracts or reorders IEEE-754 operations:
//!
//! * **Per-lane dot** ([`LanePath::dot_lanes`]): four strided partial sums
//!   over `len/4` chunks, a sequential remainder, and the final
//!   `(s0 + s1) + (s2 + s3) + rest` — exactly the association of [`dot`],
//!   which lives here and is re-exported by [`crate::linalg`]. A lane's
//!   dot therefore has the same bits as the pre-batch per-datum
//!   `dot(row, theta)`, so likelihood and bound values are independent of
//!   how data are grouped into tiles.
//! * **Cross-lane reduction** ([`tree8`]): gradient contributions of one
//!   tile fold as `((p0+p1)+(p2+p3)) + ((p4+p5)+(p6+p7))` per feature.
//!   Dead lanes of a partial tile are zero-padded (zeroed coefficients ×
//!   zeroed features), and adding `+0.0` cannot change an accumulator that
//!   is not `-0.0` — accumulators here start at `+0.0` and can never reach
//!   `-0.0` (IEEE round-to-nearest only yields `-0.0` from a sum when both
//!   addends are `-0.0`) — so a batch of one datum reproduces the old
//!   per-datum `axpy` bits exactly.
//!
//! The per-lane transcendental steps (`log_sigmoid`, `logsumexp`,
//! `ln_1p`, …) are shared scalar code between the two paths, outside the
//! `LanePath` trait, so they cannot diverge.
//!
//! Tiles are column-major ([`W`] lanes per feature: element `j` of lane
//! `l` lives at `tile[j * W + l]`), filled by
//! [`crate::data::store::DataStore::gather_tile`] through the same
//! caller-owned row cache as the scalar path. All kernels walk an index
//! batch in `W`-sized chunks and write into caller-sized slices; nothing
//! here allocates (the tile and lane buffers ride in
//! [`crate::models::EvalScratch`]).

pub mod logistic;
pub mod robust;
pub mod softmax;

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of an SoA feature tile: every batch kernel processes `W`
/// data points per tile, and [`tree8`] is the canonical reduction over one
/// tile's lanes. Fixed at 8 (four f64 AVX2 registers / one AVX-512
/// register worth of doubles); the shard size of
/// [`crate::runtime::ParBackend`] is a multiple of it, so serial and
/// sharded tiling agree on tile boundaries.
pub const W: usize = 8;

/// Dot product. The single hottest scalar kernel in the CPU backend
/// (every likelihood evaluation is one of these per datum); unrolled
/// 4-wide so LLVM vectorizes it. This association — four strided partials,
/// sequential remainder, `(s0 + s1) + (s2 + s3) + rest` — is the *canonical
/// dot tree*: [`LanePath::dot_lanes`] reproduces it per lane, which is why
/// batched likelihoods are bit-identical to per-datum ones.
// lint: zero-alloc
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut rest = 0.0;
    for i in chunks * 4..a.len() {
        rest += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + rest
}

/// y += alpha * x.
// lint: zero-alloc
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// The canonical cross-lane reduction tree over one tile:
/// `((p0+p1)+(p2+p3)) + ((p4+p5)+(p6+p7))`. Every gradient accumulation
/// and every batched bound-product sum folds its `W` lane contributions
/// through this fixed association, so the result is independent of which
/// path computed the lanes. firefly-lint's `float-reduce-order` recognizes
/// reductions routed through this helper as ordered.
// lint: zero-alloc
#[inline]
pub fn tree8(p: &[f64; W]) -> f64 {
    ((p[0] + p[1]) + (p[2] + p[3])) + ((p[4] + p[5]) + (p[6] + p[7]))
}

/// Fold per-datum gradient *product rows* into `grad` with the exact op
/// sequence of the batch kernels' [`LanePath::acc_grad_tile`] folds.
///
/// `rows` is `m × dim` row-major: `rows[i * dim + c]` holds the raw
/// single-multiply product `coeff_i · x_i[j]` (for softmax, component
/// `c = kk·d + j` holds `coeff_{kk,i} · x_i[j]` — the kernels' class
/// segments flatten to exactly this `kk`-major, `j`-minor order). The fold
/// walks the rows in `W`-sized chunks — the same chunk boundaries
/// `idx.chunks(W)` gives the batch kernels — and adds one [`tree8`] per
/// gradient component per chunk, with literal `+0.0` products for the dead
/// lanes of a partial final chunk (bit-identical to the kernels'
/// zero-padded tiles: zeroed coefficients × zeroed features).
///
/// Because each product is a single IEEE multiply of
/// composition-invariant inputs (per-lane dots equal the canonical
/// [`dot`]; features are gathered bits), rows computed *anywhere* — by a
/// shard worker tiling only its own sub-batch, in another process — fold
/// here to the same bits as [`LanePath::acc_grad_tile`] over the full
/// batch. This is the reduction that keeps the distributed backend's
/// gradients byte-identical to `CpuBackend` at any worker count
/// (DESIGN.md §Distribution). firefly-lint's `float-reduce-order` treats
/// reductions routed through this helper as ordered.
// lint: zero-alloc
pub fn fold_grad_rows(rows: &[f64], dim: usize, grad: &mut [f64]) {
    debug_assert_eq!(grad.len(), dim);
    if dim == 0 {
        return;
    }
    debug_assert_eq!(rows.len() % dim, 0);
    let m = rows.len() / dim;
    let mut start = 0;
    while start < m {
        let live = (m - start).min(W);
        for (c, g) in grad.iter_mut().enumerate() {
            let mut p = [0.0; W];
            for (l, pl) in p.iter_mut().enumerate().take(live) {
                *pl = rows[(start + l) * dim + c];
            }
            *g += tree8(&p);
        }
        start += live;
    }
}

/// One implementation of the lane-level primitives every batch kernel is
/// generic over. Implementations must follow the canonical association
/// trees documented on [`dot`] and [`tree8`] exactly — the module-level
/// bit-exactness contract (and the `integration_kernels` suite) holds each
/// of them to the same bits.
pub trait LanePath {
    /// Human-readable path name for bench/diagnostic labels.
    const NAME: &'static str;

    /// Per-lane canonical dot: `out[l] = dot(theta, column l of tile)`
    /// with the association of [`dot`]. `tile` is column-major
    /// (`theta.len() × W`, element `j` of lane `l` at `tile[j * W + l]`).
    fn dot_lanes(theta: &[f64], tile: &[f64], out: &mut [f64; W]);

    /// Per-feature gradient accumulation over one tile:
    /// `grad[j] += tree8([coeff[l] * tile[j * W + l]; W])`. Dead lanes
    /// must carry `coeff[l] == 0.0` (and gathered tiles zero-pad dead
    /// features), so partial tiles contribute exact `+0.0` products.
    fn acc_grad_tile(coeff: &[f64; W], tile: &[f64], grad: &mut [f64]);
}

/// Lane-outer scalar reference path: one datum at a time, strided tile
/// reads — the shape of the pre-batch per-datum code, kept as the
/// executable specification the fast path is checked against.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarPath;

impl LanePath for ScalarPath {
    const NAME: &'static str = "scalar";

    // lint: zero-alloc
    #[inline]
    fn dot_lanes(theta: &[f64], tile: &[f64], out: &mut [f64; W]) {
        let d = theta.len();
        debug_assert_eq!(tile.len(), d * W);
        let chunks = d / 4;
        for (l, o) in out.iter_mut().enumerate() {
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for c in 0..chunks {
                let j = c * 4;
                s0 += tile[j * W + l] * theta[j];
                s1 += tile[(j + 1) * W + l] * theta[j + 1];
                s2 += tile[(j + 2) * W + l] * theta[j + 2];
                s3 += tile[(j + 3) * W + l] * theta[j + 3];
            }
            let mut rest = 0.0;
            for j in chunks * 4..d {
                rest += tile[j * W + l] * theta[j];
            }
            *o = (s0 + s1) + (s2 + s3) + rest;
        }
    }

    // lint: zero-alloc
    #[inline]
    fn acc_grad_tile(coeff: &[f64; W], tile: &[f64], grad: &mut [f64]) {
        debug_assert_eq!(tile.len(), grad.len() * W);
        for (j, g) in grad.iter_mut().enumerate() {
            let col = &tile[j * W..j * W + W];
            let p0 = coeff[0] * col[0];
            let p1 = coeff[1] * col[1];
            let p2 = coeff[2] * col[2];
            let p3 = coeff[3] * col[3];
            let p4 = coeff[4] * col[4];
            let p5 = coeff[5] * col[5];
            let p6 = coeff[6] * col[6];
            let p7 = coeff[7] * col[7];
            *g += ((p0 + p1) + (p2 + p3)) + ((p4 + p5) + (p6 + p7));
        }
    }
}

/// Feature-outer autovectorized fast path: fixed-width `[f64; W]`
/// accumulator arrays updated across contiguous tile columns — each lane's
/// own operation sequence is identical to [`ScalarPath`]'s (independent
/// accumulators, same order within each), so the bits match while LLVM is
/// free to map the `W`-wide inner loops onto vector registers.
#[derive(Clone, Copy, Debug, Default)]
pub struct FastPath;

impl LanePath for FastPath {
    const NAME: &'static str = "fast";

    // lint: zero-alloc
    #[inline]
    fn dot_lanes(theta: &[f64], tile: &[f64], out: &mut [f64; W]) {
        let d = theta.len();
        debug_assert_eq!(tile.len(), d * W);
        let chunks = d / 4;
        let mut s0 = [0.0; W];
        let mut s1 = [0.0; W];
        let mut s2 = [0.0; W];
        let mut s3 = [0.0; W];
        for c in 0..chunks {
            let j = c * 4;
            let base = j * W;
            let (t0, t1, t2, t3) = (theta[j], theta[j + 1], theta[j + 2], theta[j + 3]);
            let cols = &tile[base..base + 4 * W];
            for l in 0..W {
                s0[l] += cols[l] * t0;
                s1[l] += cols[W + l] * t1;
                s2[l] += cols[2 * W + l] * t2;
                s3[l] += cols[3 * W + l] * t3;
            }
        }
        let mut rest = [0.0; W];
        for j in chunks * 4..d {
            let col = &tile[j * W..j * W + W];
            let tj = theta[j];
            for l in 0..W {
                rest[l] += col[l] * tj;
            }
        }
        for l in 0..W {
            out[l] = (s0[l] + s1[l]) + (s2[l] + s3[l]) + rest[l];
        }
    }

    // lint: zero-alloc
    #[inline]
    fn acc_grad_tile(coeff: &[f64; W], tile: &[f64], grad: &mut [f64]) {
        debug_assert_eq!(tile.len(), grad.len() * W);
        let mut p = [0.0; W];
        for (j, g) in grad.iter_mut().enumerate() {
            let col = &tile[j * W..j * W + W];
            for l in 0..W {
                p[l] = coeff[l] * col[l];
            }
            *g += tree8(&p);
        }
    }
}

/// Which [`LanePath`] the models' batch methods route through — a
/// process-wide switch because the paths are interchangeable by
/// construction (identical bits) and threading a preference through every
/// model/backend constructor would buy nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// [`ScalarPath`] — the lane-outer reference loops.
    Scalar,
    /// [`FastPath`] — the feature-outer autovectorized loops (default).
    Fast,
}

static ACTIVE_PATH: AtomicU8 = AtomicU8::new(1);

/// Select the process-wide kernel path. Default is [`KernelPath::Fast`];
/// tests and benches flip it to prove the paths agree bit-for-bit on whole
/// chains. Relaxed ordering is sufficient: either value is correct, the
/// switch only chooses between bit-identical implementations.
pub fn set_kernel_path(p: KernelPath) {
    ACTIVE_PATH.store(p as u8, Ordering::Relaxed);
}

/// The currently selected process-wide kernel path.
pub fn kernel_path() -> KernelPath {
    if ACTIVE_PATH.load(Ordering::Relaxed) == KernelPath::Scalar as u8 {
        KernelPath::Scalar
    } else {
        KernelPath::Fast
    }
}

/// Dispatch a generic batch kernel over the active [`KernelPath`] — the
/// one place the runtime switch meets the compile-time [`LanePath`]
/// monomorphizations.
macro_rules! dispatch_path {
    ($path:expr, $f:path, ($($arg:expr),* $(,)?)) => {
        match $path {
            $crate::kernels::KernelPath::Scalar => $f::<$crate::kernels::ScalarPath>($($arg),*),
            $crate::kernels::KernelPath::Fast => $f::<$crate::kernels::FastPath>($($arg),*),
        }
    };
}
pub(crate) use dispatch_path;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(1);
        for len in [0, 1, 3, 4, 7, 51, 256] {
            let a: Vec<f64> = (0..len).map(|_| r.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| r.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10, "len {len}");
        }
    }

    fn random_tile(d: usize, r: &mut Rng) -> Vec<f64> {
        (0..d * W).map(|_| r.normal()).collect()
    }

    #[test]
    fn dot_lanes_paths_bitwise_equal_and_match_scalar_dot() {
        let mut r = Rng::new(7);
        for d in [0usize, 1, 2, 3, 4, 5, 7, 8, 12, 33, 100] {
            let theta: Vec<f64> = (0..d).map(|_| r.normal()).collect();
            let tile = random_tile(d, &mut r);
            let mut scalar = [0.0; W];
            let mut fast = [0.0; W];
            ScalarPath::dot_lanes(&theta, &tile, &mut scalar);
            FastPath::dot_lanes(&theta, &tile, &mut fast);
            for l in 0..W {
                assert_eq!(scalar[l].to_bits(), fast[l].to_bits(), "d={d} lane {l}");
                // and both equal the canonical dot of the de-transposed row
                let row: Vec<f64> = (0..d).map(|j| tile[j * W + l]).collect();
                assert_eq!(
                    scalar[l].to_bits(),
                    dot(&row, &theta).to_bits(),
                    "d={d} lane {l} vs canonical dot"
                );
            }
        }
    }

    #[test]
    fn acc_grad_paths_bitwise_equal() {
        let mut r = Rng::new(8);
        for d in [1usize, 3, 8, 17, 64] {
            let tile = random_tile(d, &mut r);
            let mut coeff = [0.0; W];
            for c in &mut coeff {
                *c = r.normal();
            }
            let mut ga = vec![0.0; d];
            let mut gb = vec![0.0; d];
            ScalarPath::acc_grad_tile(&coeff, &tile, &mut ga);
            FastPath::acc_grad_tile(&coeff, &tile, &mut gb);
            for j in 0..d {
                assert_eq!(ga[j].to_bits(), gb[j].to_bits(), "d={d} j={j}");
            }
        }
    }

    #[test]
    fn single_live_lane_reproduces_axpy_bits() {
        // batch-of-1 == old per-datum axpy: products of the dead lanes are
        // +0.0 and tree8 folds them away without touching the live bits
        let mut r = Rng::new(9);
        for d in [1usize, 5, 16, 51] {
            let row: Vec<f64> = (0..d).map(|_| r.normal()).collect();
            let alpha = r.normal();
            let mut tile = vec![0.0; d * W];
            for j in 0..d {
                tile[j * W] = row[j];
            }
            let mut coeff = [0.0; W];
            coeff[0] = alpha;
            let mut g_tile = vec![0.0; d];
            FastPath::acc_grad_tile(&coeff, &tile, &mut g_tile);
            let mut g_axpy = vec![0.0; d];
            axpy(alpha, &row, &mut g_axpy);
            for j in 0..d {
                assert_eq!(g_tile[j].to_bits(), g_axpy[j].to_bits(), "d={d} j={j}");
            }
        }
    }

    #[test]
    fn fold_grad_rows_replays_acc_grad_tile_bits() {
        // Rows carrying the raw per-lane products of each tile must fold to
        // the same bits as acc_grad_tile over the tiles — including a
        // partial final chunk (dead lanes = literal +0.0 vs the kernels'
        // zero-padded 0.0 * 0.0 products).
        let mut r = Rng::new(41);
        for (m, d) in [(1usize, 5usize), (7, 3), (8, 4), (19, 6), (24, 1)] {
            let mut rows = vec![0.0; m * d];
            let mut expect = vec![0.0; d];
            let mut i = 0;
            while i < m {
                let live = (m - i).min(W);
                let mut tile = vec![0.0; d * W];
                let mut coeff = [0.0; W];
                for l in 0..live {
                    coeff[l] = r.normal();
                    for j in 0..d {
                        tile[j * W + l] = r.normal();
                    }
                }
                // the raw products, as a worker would ship them
                for l in 0..live {
                    for j in 0..d {
                        rows[(i + l) * d + j] = coeff[l] * tile[j * W + l];
                    }
                }
                FastPath::acc_grad_tile(&coeff, &tile, &mut expect);
                i += live;
            }
            let mut got = vec![0.0; d];
            fold_grad_rows(&rows, d, &mut got);
            for j in 0..d {
                assert_eq!(got[j].to_bits(), expect[j].to_bits(), "m={m} d={d} j={j}");
            }
        }
        // empty batch and dim-0 are no-ops
        let mut g = vec![1.25; 3];
        fold_grad_rows(&[], 3, &mut g);
        assert_eq!(g, vec![1.25; 3]);
        fold_grad_rows(&[], 0, &mut []);
    }

    #[test]
    fn tree8_is_the_documented_association() {
        let p = [1e16, 1.0, -1e16, 1.0, 3.0, -2.0, 0.5, 0.25];
        let expect = ((p[0] + p[1]) + (p[2] + p[3])) + ((p[4] + p[5]) + (p[6] + p[7]));
        assert_eq!(tree8(&p).to_bits(), expect.to_bits());
    }

    #[test]
    fn kernel_path_switch_roundtrips() {
        let before = kernel_path();
        set_kernel_path(KernelPath::Scalar);
        assert_eq!(kernel_path(), KernelPath::Scalar);
        set_kernel_path(KernelPath::Fast);
        assert_eq!(kernel_path(), KernelPath::Fast);
        set_kernel_path(before);
    }
}
