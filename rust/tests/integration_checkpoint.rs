//! Acceptance tests for the checkpointable chain runtime (DESIGN.md
//! §Checkpointing): for every paper workload (logistic + RW-MH, softmax +
//! MALA, robust + slice) on both CPU backends, a chain that is
//! checkpointed, "killed" mid-run (session-bounded via `stop_after`) and
//! resumed in a fresh process-equivalent (fresh model/backend/sampler
//! build, state restored from the `.fckpt`) must produce **byte-identical**
//! θ traces, diagnostics inputs (log-posterior series, streaming moments,
//! ESS/R̂ inputs), bright trajectories, and query counters to the
//! never-interrupted run. Also here: the streaming-vs-trace moment
//! tolerance contract, config-drift rejection, and the zero-allocation
//! steady state with the full observer pipeline attached.
//!
//! The binary hosts the counting global allocator for the zero-alloc test,
//! so every test serializes through one mutex — a concurrently-running
//! sibling test would otherwise pollute the allocation window.

use std::sync::Mutex;

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::engine::experiment::{build_chain, build_model, build_sampler};
use firefly::engine::{
    run_experiment, run_experiment_resume, ChainConfig, ChainResult, ChainState,
    CheckpointObserver, RecordingObserver, StreamingObserver,
};
use firefly::engine::observer::ChainObserver;
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::math::{mean, variance};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Serializes all tests in this binary (see module docs).
static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_dir(name: &str) -> String {
    let p = std::env::temp_dir().join(format!("firefly_itckpt_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p.to_string_lossy().into_owned()
}

fn assert_chain_identical(a: &ChainResult, b: &ChainResult, label: &str) {
    assert_eq!(a.seed, b.seed, "{label}: seeds differ");
    assert_eq!(
        a.logpost_joint.len(),
        b.logpost_joint.len(),
        "{label}: iteration counts differ"
    );
    for (i, (x, y)) in a.logpost_joint.iter().zip(&b.logpost_joint).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: logpost differs at iter {i}");
    }
    assert_eq!(a.theta_trace.n_rows(), b.theta_trace.n_rows(), "{label}: trace rows");
    for i in 0..a.theta_trace.n_rows() {
        for (x, y) in a.theta_trace.row(i).iter().zip(b.theta_trace.row(i)) {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: θ trace differs at row {i}");
        }
    }
    assert_eq!(a.full_logpost.len(), b.full_logpost.len(), "{label}");
    for ((ia, va), (ib, vb)) in a.full_logpost.iter().zip(&b.full_logpost) {
        assert_eq!(ia, ib, "{label}: full-logpost tick drifted");
        assert_eq!(va.to_bits(), vb.to_bits(), "{label}: full logpost differs");
    }
    assert_eq!(a.bright, b.bright, "{label}: bright trajectories differ");
    assert_eq!(a.queries_per_iter, b.queries_per_iter, "{label}: query accounting differs");
    assert_eq!(a.accepted, b.accepted, "{label}");
    assert_eq!(a.z_brightened, b.z_brightened, "{label}");
    assert_eq!(a.z_darkened, b.z_darkened, "{label}");
    assert_eq!(a.final_counters, b.final_counters, "{label}: counter totals differ");
    // streaming diagnostics inputs are part of the identity contract
    assert_eq!(a.stats.rows, b.stats.rows, "{label}");
    assert_eq!(a.stats.batch_size, b.stats.batch_size, "{label}");
    for (j, (x, y)) in a.stats.mean.iter().zip(&b.stats.mean).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: streaming mean differs at {j}");
    }
    for (j, (x, y)) in a.stats.var.iter().zip(&b.stats.var).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: streaming var differs at {j}");
    }
    assert_eq!(
        a.stats.ess_bm_min.to_bits(),
        b.stats.ess_bm_min.to_bits(),
        "{label}: batch-means ESS differs"
    );
    assert_eq!(
        a.stats.split_rhat_halves.to_bits(),
        b.stats.split_rhat_halves.to_bits(),
        "{label}: split-R̂ halves differ"
    );
    assert_eq!(a.stats.bright, b.stats.bright, "{label}: bright stats differ");
    assert_eq!(a.stats.iters_post_burnin, b.stats.iters_post_burnin, "{label}");
    assert_eq!(
        a.stats.queries_post_burnin, b.stats.queries_post_burnin,
        "{label}: streaming query aggregate differs"
    );
}

fn workload_cfg(task: Task, backend: Backend) -> ExperimentConfig {
    let (algorithm, n, iters, burnin, map_steps) = match task {
        // logistic + RW-MH, through the MAP-tuning pre-pass (its queries
        // and anchor state must be rebuilt deterministically on resume)
        Task::LogisticMnist => (Algorithm::MapTunedFlyMc, 300, 100, 30, 50),
        // softmax + MALA: the gradient path and its current-point cache
        Task::SoftmaxCifar => (Algorithm::UntunedFlyMc, 120, 60, 20, 0),
        // robust + slice: variable evals/iteration
        Task::RobustOpv => (Algorithm::UntunedFlyMc, 300, 60, 20, 0),
        Task::Toy => unreachable!("not a paper workload"),
    };
    ExperimentConfig {
        task,
        algorithm,
        backend,
        n_data: Some(n),
        iters,
        burnin,
        map_steps,
        chains: 1,
        record_every: 13,
        seed: 42,
        ..Default::default()
    }
}

/// Reference (uninterrupted, no checkpointing) vs killed-and-resumed:
/// byte-identical end state for one workload/backend pair.
fn check_resume_identity(task: Task, backend: Backend, label: &str) {
    let dir = tmp_dir(label);
    let reference = run_experiment(&workload_cfg(task, backend)).expect("reference run");

    // session 1: checkpoint every 20, preempted after 33 iterations
    let mut partial_cfg = workload_cfg(task, backend);
    partial_cfg.checkpoint_dir = Some(dir.clone());
    partial_cfg.checkpoint_every = 20;
    partial_cfg.stop_after = Some(33);
    let partial = run_experiment(&partial_cfg).expect("partial run");
    assert_eq!(
        partial.chains[0].logpost_joint.len(),
        33,
        "{label}: session bound ignored"
    );

    // session 2: fresh build, resume to completion
    let mut resume_cfg = workload_cfg(task, backend);
    resume_cfg.checkpoint_dir = Some(dir.clone());
    resume_cfg.checkpoint_every = 20;
    let resumed = run_experiment_resume(&resume_cfg, true).expect("resumed run");

    assert_eq!(reference.chains.len(), resumed.chains.len());
    for (a, b) in reference.chains.iter().zip(&resumed.chains) {
        assert_chain_identical(a, b, label);
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn logistic_rwmh_resume_byte_identical_cpu_and_parcpu() {
    let _g = lock();
    check_resume_identity(Task::LogisticMnist, Backend::Cpu, "logistic/cpu");
    check_resume_identity(Task::LogisticMnist, Backend::ParCpu, "logistic/parcpu");
}

#[test]
fn softmax_mala_resume_byte_identical_cpu_and_parcpu() {
    let _g = lock();
    check_resume_identity(Task::SoftmaxCifar, Backend::Cpu, "softmax/cpu");
    check_resume_identity(Task::SoftmaxCifar, Backend::ParCpu, "softmax/parcpu");
}

#[test]
fn robust_slice_resume_byte_identical_cpu_and_parcpu() {
    let _g = lock();
    check_resume_identity(Task::RobustOpv, Backend::Cpu, "robust/cpu");
    check_resume_identity(Task::RobustOpv, Backend::ParCpu, "robust/parcpu");
}

#[test]
fn multi_replica_experiment_resumes_and_is_idempotent() {
    let _g = lock();
    let dir = tmp_dir("multi");
    let base = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(250),
        iters: 80,
        burnin: 20,
        chains: 3,
        threads: 2,
        record_every: 0,
        seed: 7,
        ..Default::default()
    };
    let reference = run_experiment(&base).unwrap();

    let mut partial_cfg = base.clone();
    partial_cfg.checkpoint_dir = Some(dir.clone());
    partial_cfg.checkpoint_every = 25;
    partial_cfg.stop_after = Some(40);
    run_experiment(&partial_cfg).unwrap();

    let mut resume_cfg = base.clone();
    resume_cfg.checkpoint_dir = Some(dir.clone());
    resume_cfg.checkpoint_every = 25;
    let resumed = run_experiment_resume(&resume_cfg, true).unwrap();
    assert_eq!(resumed.chains.len(), 3);
    for (r, (a, b)) in reference.chains.iter().zip(&resumed.chains).enumerate() {
        assert_chain_identical(a, b, &format!("replica {r}"));
    }

    // resuming a *finished* experiment replays the final checkpoints (zero
    // further iterations) and must reproduce the same output again
    let again = run_experiment_resume(&resume_cfg, true).unwrap();
    for (r, (a, b)) in resumed.chains.iter().zip(&again.chains).enumerate() {
        assert_chain_identical(a, b, &format!("idempotent replica {r}"));
    }
    // the summary the operator sees is the same one, too
    let (a, b) = (reference.table_row(), again.table_row());
    assert_eq!(a.avg_lik_queries_per_iter.to_bits(), b.avg_lik_queries_per_iter.to_bits());
    assert_eq!(a.ess_per_1000.to_bits(), b.ess_per_1000.to_bits());
    assert_eq!(a.split_rhat.to_bits(), b.split_rhat.to_bits());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn streaming_only_mode_keeps_summaries_and_resumes_identically() {
    let _g = lock();
    let dir = tmp_dir("streaming_only");
    let base = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(250),
        iters: 120,
        burnin: 30,
        chains: 1,
        record_every: 0,
        seed: 19,
        record_trace: false,
        ..Default::default()
    };

    // the recorded-mode twin pins the streaming summary's accuracy
    let mut recorded_cfg = base.clone();
    recorded_cfg.record_trace = true;
    let recorded = run_experiment(&recorded_cfg).unwrap();

    let reference = run_experiment(&base).unwrap();
    let chain = &reference.chains[0];
    // bounded mode: no series at all...
    assert!(chain.theta_trace.is_empty());
    assert!(chain.logpost_joint.is_empty());
    assert!(chain.queries_per_iter.is_empty());
    // ...yet the summary columns survive via the streaming aggregates
    let row = reference.table_row();
    assert!(row.avg_lik_queries_per_iter.is_finite());
    assert!(row.ess_per_1000.is_finite() && row.ess_per_1000 > 0.0);
    assert!(row.avg_bright.is_finite());
    let rec_chain = &recorded.chains[0];
    assert!(
        (chain.avg_queries_post_burnin(base.burnin)
            - rec_chain.avg_queries_post_burnin(base.burnin))
        .abs()
            < 1e-9,
        "streaming queries/iter disagrees with the recorded series"
    );
    assert_eq!(chain.stats.bright, rec_chain.stats.bright);

    // kill-and-resume identity holds in streaming-only mode too
    let mut partial_cfg = base.clone();
    partial_cfg.checkpoint_dir = Some(dir.clone());
    partial_cfg.checkpoint_every = 25;
    partial_cfg.stop_after = Some(40);
    run_experiment(&partial_cfg).unwrap();
    let mut resume_cfg = base.clone();
    resume_cfg.checkpoint_dir = Some(dir.clone());
    resume_cfg.checkpoint_every = 25;
    let resumed = run_experiment_resume(&resume_cfg, true).unwrap();
    assert_chain_identical(&reference.chains[0], &resumed.chains[0], "streaming-only");

    // toggling the recording mode between sessions is refused up front
    // (it is part of the config fingerprint)
    let mut toggled = resume_cfg.clone();
    toggled.record_trace = true;
    let err = run_experiment_resume(&toggled, true).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn resume_rejects_config_drift() {
    let _g = lock();
    let dir = tmp_dir("drift");
    let mut cfg = workload_cfg(Task::LogisticMnist, Backend::Cpu);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.checkpoint_every = 20;
    cfg.stop_after = Some(30);
    run_experiment(&cfg).unwrap();

    // same directory, different seed => different fingerprint => refused
    let mut drifted = cfg.clone();
    drifted.stop_after = None;
    drifted.seed = 43;
    let err = run_experiment_resume(&drifted, true).unwrap_err();
    assert!(
        format!("{err:#}").contains("fingerprint"),
        "want a fingerprint-mismatch error, got: {err:#}"
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn streaming_moments_match_trace_derived_moments() {
    let _g = lock();
    // contract (DESIGN.md §Checkpointing): streaming mean/variance within
    // 1e-8 relative of the batch TraceMatrix-derived values; the halves
    // split-R̂ within 1e-6 of the same formula over materialized halves
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(250),
        iters: 220,
        burnin: 20,
        chains: 1,
        record_every: 0,
        seed: 11,
        ..Default::default()
    };
    let res = run_experiment(&cfg).unwrap();
    let chain = &res.chains[0];
    let trace = &chain.theta_trace;
    assert_eq!(chain.stats.rows, trace.n_rows());
    let mut col = Vec::new();
    for j in 0..trace.dim() {
        trace.column_into(j, &mut col);
        let (bm, bv) = (mean(&col), variance(&col));
        let (sm, sv) = (chain.stats.mean[j], chain.stats.var[j]);
        assert!(
            (sm - bm).abs() <= 1e-8 * (1.0 + bm.abs()),
            "component {j}: streaming mean {sm} vs trace {bm}"
        );
        assert!(
            (sv - bv).abs() <= 1e-8 * (1.0 + bv.abs()),
            "component {j}: streaming var {sv} vs trace {bv}"
        );
    }
    // split-R̂ halves: reference from the materialized trace halves
    let h = trace.n_rows() / 2;
    let mut worst = f64::NEG_INFINITY;
    for j in 0..trace.dim() {
        trace.column_into(j, &mut col);
        let (c1, c2) = (&col[..h], &col[h..2 * h]);
        let (m1, m2) = (mean(c1), mean(c2));
        let w = 0.5 * (variance(c1) + variance(c2));
        if !(w > 0.0) {
            continue;
        }
        let g = 0.5 * (m1 + m2);
        let hf = h as f64;
        let b = hf * ((m1 - g) * (m1 - g) + (m2 - g) * (m2 - g));
        let r = (((hf - 1.0) / hf * w + b / hf) / w).sqrt();
        if r.is_finite() {
            worst = worst.max(r);
        }
    }
    let got = chain.stats.split_rhat_halves;
    assert!(
        (got - worst).abs() <= 1e-6 * (1.0 + worst.abs()),
        "split-R̂ halves {got} vs trace-derived {worst}"
    );
    // ESS sanity: defined and within [1, rows]
    let ess = chain.stats.ess_bm_min;
    assert!(ess >= 1.0 && ess <= chain.stats.rows as f64, "ESS {ess}");
}

#[test]
fn zero_alloc_steady_state_with_full_observer_pipeline() {
    let _g = lock();
    // The zero-allocation steady-state invariant (DESIGN.md §Perf) must
    // survive the observer refactor with the streaming observer AND an
    // armed checkpoint writer attached — checkpoint writes themselves are
    // boundary events, excluded from the counting window (the writer's
    // cadence is set beyond the window).
    let dir = tmp_dir("alloc");
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(300),
        iters: 500,
        burnin: 50,
        chains: 1,
        record_every: 0, // true_log_posterior allocates by design
        seed: 3,
        ..Default::default()
    };
    let (model, prior, _, _) = build_model(&cfg).unwrap();
    let (target, theta0) = build_chain(&cfg, model, prior, cfg.seed).unwrap();
    let sampler = build_sampler(cfg.task);
    let ccfg = ChainConfig {
        iters: cfg.iters,
        burnin: cfg.burnin,
        record_full_every: 0,
        thin: 1,
        q_dark_to_bright: cfg.effective_q_db(),
        explicit_resample: false,
        resample_fraction: 0.1,
        seed: cfg.seed,
        record_trace: true,
        ..Default::default()
    };
    let dim = theta0.len();
    let mut state = ChainState::new(target, sampler, theta0, &ccfg);
    let mut rec = RecordingObserver::new(&ccfg, dim);
    let mut stats = StreamingObserver::new(&ccfg, dim);
    // armed writer whose first boundary lies beyond the measured window
    let mut writer = CheckpointObserver::new(&format!("{dir}/chain.fckpt"), 100_000, 1);
    let mut observers: [&mut dyn ChainObserver; 3] = [&mut rec, &mut stats, &mut writer];

    state.run_for(100, &mut observers).unwrap(); // warm-up
    let before = ALLOC.allocations();
    state.run_for(300, &mut observers).unwrap();
    let allocs = ALLOC.allocations() - before;
    assert_eq!(
        allocs, 0,
        "steady-state iterations with recording + streaming + checkpoint \
         observers performed {allocs} heap allocations"
    );
    // finish (final checkpoint write happens here, outside the window)
    state.run_to_end(&mut observers).unwrap();
    assert_eq!(writer.writes(), 1, "completion forces exactly one write");
    let res = state.into_result(rec, stats);
    assert_eq!(res.logpost_joint.len(), 500);
    assert!(res.stats.bright.count > 0);
    let _ = std::fs::remove_dir_all(dir);
}
