//! Acceptance tests for the batched SoA kernel layer (DESIGN.md §Kernels).
//!
//! Two bit-level contracts are pinned here:
//!
//! 1. **Path identity** — the scalar reference lane path and the
//!    autovectorized fast path produce identical bits for every kernel, on
//!    every model, for every batch shape, and therefore byte-identical
//!    full chains on all three paper workloads × both CPU backends ×
//!    dense/block storage.
//! 2. **Composition identity** — likelihood/bound values from a batch call
//!    equal the per-datum (batch-of-1) wrapper values bit-for-bit, and
//!    both equal an independently coded oracle of the pre-refactor
//!    per-datum formulas. Gradients fold through a different (documented)
//!    reduction tree, so batch vs per-datum gradients are compared to
//!    tight relative tolerance instead.
//!
//! The kernel-path switch is process-global, so every test here holds one
//! shared lock while flipping it; this binary is the only place the switch
//! is exercised outside `benches/hotpath.rs`.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::data::fbin::{open_fbin, write_fbin};
use firefly::data::store::{BlockCacheConfig, RowCache};
use firefly::data::{synth, AnyData, SoftmaxData};
use firefly::engine::{run_experiment, synth_dataset, ChainResult};
use firefly::kernels::{set_kernel_path, KernelPath};
use firefly::linalg::{dot, Matrix};
use firefly::models::logistic::jj_coeffs;
use firefly::models::{LogisticJJ, ModelBound, RobustT, SoftmaxBohning};
use firefly::util::math::{log_sigmoid, logsumexp, t_logconst};
use firefly::util::Rng;

/// The kernel-path switch is process-global; tests that flip it hold this.
fn path_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("firefly_itkern_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn assert_bits(a: &[f64], b: &[f64], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: bits differ at {i}: {x} vs {y}");
    }
}

fn assert_close(a: &[f64], b: &[f64], rel: f64, label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= rel * (1.0 + x.abs().max(y.abs())),
            "{label}: {x} vs {y} at {i}"
        );
    }
}

struct BatchOut {
    ll: Vec<f64>,
    lb: Vec<f64>,
    gp: Vec<f64>,
    gl: Vec<f64>,
    bp: f64,
}

/// Evaluate all five batch kernels under `path`, cross-checking that the
/// fused and unfused entry points agree bitwise on the values they share.
fn eval_batch(m: &dyn ModelBound, theta: &[f64], idx: &[u32], path: KernelPath) -> BatchOut {
    set_kernel_path(path);
    let mut sc = m.new_scratch();
    let k = idx.len();
    let (mut ll, mut lb) = (vec![0.0; k], vec![0.0; k]);
    let (mut gp, mut gl) = (vec![0.0; m.dim()], vec![0.0; m.dim()]);
    m.pseudo_grad_batch(theta, idx, &mut ll, &mut lb, &mut gp, &mut sc);
    let (mut ll2, mut lb2) = (vec![0.0; k], vec![0.0; k]);
    m.log_both_batch(theta, idx, &mut ll2, &mut lb2, &mut sc);
    assert_bits(&ll, &ll2, "pseudo_grad ll == log_both ll");
    assert_bits(&lb, &lb2, "pseudo_grad lb == log_both lb");
    let mut ll3 = vec![0.0; k];
    m.log_lik_batch(theta, idx, &mut ll3, &mut sc);
    assert_bits(&ll, &ll3, "log_lik ll == log_both ll");
    let mut ll4 = vec![0.0; k];
    m.log_lik_grad_batch(theta, idx, &mut ll4, &mut gl, &mut sc);
    assert_bits(&ll, &ll4, "log_lik_grad ll == log_both ll");
    let bp = m.log_bound_product_batch(theta, idx, &mut sc);
    BatchOut { ll, lb, gp, gl, bp }
}

/// The pre-refactor evaluation order: one datum at a time through the
/// per-datum `ModelBound` API (now batch-of-1 wrappers), gradients
/// accumulated sequentially, bound product summed left-to-right.
fn eval_per_datum(m: &dyn ModelBound, theta: &[f64], idx: &[u32]) -> BatchOut {
    set_kernel_path(KernelPath::Scalar);
    let mut sc = m.new_scratch();
    let (mut ll, mut lb) = (Vec::new(), Vec::new());
    let (mut gp, mut gl) = (vec![0.0; m.dim()], vec![0.0; m.dim()]);
    let mut bp = 0.0;
    for &n in idx {
        let (l, b) = m.log_both(theta, n as usize, &mut sc);
        ll.push(l);
        lb.push(b);
        m.pseudo_grad_acc(theta, n as usize, &mut gp, &mut sc);
        m.log_lik_grad_acc(theta, n as usize, &mut gl, &mut sc);
        bp += b;
    }
    BatchOut { ll, lb, gp, gl, bp }
}

/// Independently coded pre-refactor formulas (the canonical `linalg::dot`
/// association, which the lane dot reproduces bit-for-bit).
fn logistic_oracle(m: &LogisticJJ, theta: &[f64], n: usize, rows: &mut RowCache) -> (f64, f64) {
    let s = m.data.t[n] * dot(theta, m.data.x.row(n, rows));
    let ll = log_sigmoid(s);
    let (a, b, c) = jj_coeffs(m.xi[n]);
    (ll, (a * s * s + b * s + c).min(ll))
}

fn robust_oracle(m: &RobustT, theta: &[f64], n: usize, rows: &mut RowCache) -> (f64, f64) {
    let c2 = m.nu * m.sigma * m.sigma;
    let logc = t_logconst(m.nu, m.sigma);
    let r = m.data.y[n] - dot(theta, m.data.x.row(n, rows));
    let u = r * r;
    let ll = logc - (m.nu + 1.0) / 2.0 * (u / c2).ln_1p();
    let u0 = m.u0[n];
    let f0 = logc - (m.nu + 1.0) / 2.0 * (u0 / c2).ln_1p();
    let fp0 = -(m.nu + 1.0) / 2.0 / (c2 + u0);
    (ll, (f0 + fp0 * (u - u0)).min(ll))
}

fn softmax_ll_oracle(
    m: &SoftmaxBohning,
    theta: &[f64],
    n: usize,
    rows: &mut RowCache,
    eta: &mut [f64],
) -> f64 {
    m.logits(theta, n, rows, eta);
    eta[m.data.labels[n]] - logsumexp(eta)
}

/// Random index sets covering the lane-remainder space: full-data, a
/// below-W singleton batch, and a random-length subset (likely ≢ 0 mod 8).
fn index_sets(n: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let full: Vec<u32> = (0..n as u32).collect();
    let single = vec![rng.below(n) as u32];
    let len = 1 + rng.below(n.max(2) - 1);
    let subset: Vec<u32> = (0..len).map(|_| rng.below(n) as u32).collect();
    vec![full, single, subset]
}

/// The shared property check: scalar ≡ fast bitwise on everything; batch
/// ll/lb ≡ per-datum bitwise; batch gradients ≈ per-datum gradients.
fn check_model(m: &dyn ModelBound, rng: &mut Rng, label: &str) {
    let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
    for idx in index_sets(m.n(), rng) {
        let scalar = eval_batch(m, &theta, &idx, KernelPath::Scalar);
        let fast = eval_batch(m, &theta, &idx, KernelPath::Fast);
        assert_bits(&scalar.ll, &fast.ll, &format!("{label}: ll scalar vs fast"));
        assert_bits(&scalar.lb, &fast.lb, &format!("{label}: lb scalar vs fast"));
        assert_bits(&scalar.gp, &fast.gp, &format!("{label}: pseudo grad scalar vs fast"));
        assert_bits(&scalar.gl, &fast.gl, &format!("{label}: lik grad scalar vs fast"));
        assert_eq!(
            scalar.bp.to_bits(),
            fast.bp.to_bits(),
            "{label}: bound product scalar vs fast"
        );

        let datum = eval_per_datum(m, &theta, &idx);
        assert_bits(&scalar.ll, &datum.ll, &format!("{label}: batch ll vs per-datum"));
        assert_bits(&scalar.lb, &datum.lb, &format!("{label}: batch lb vs per-datum"));
        // gradients fold through tree8 (documented association change) —
        // tight tolerance, not bits
        assert_close(&scalar.gp, &datum.gp, 1e-9, &format!("{label}: pseudo grad"));
        assert_close(&scalar.gl, &datum.gl, 1e-9, &format!("{label}: lik grad"));
        assert_close(&[scalar.bp], &[datum.bp], 1e-9, &format!("{label}: bound product"));
    }
}

/// Softmax data with an arbitrary class count (the synth generator is
/// pinned to K = 3, and the K sweep needs more).
fn synth_softmax_k(n: usize, d: usize, k: usize, seed: u64) -> SoftmaxData {
    let mut rng = Rng::new(seed ^ 0x50f7);
    let mut x = Matrix::zeros(n, d);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        for v in x.row_mut(i) {
            *v = rng.normal() * 0.6;
        }
        labels[i] = rng.below(k);
    }
    SoftmaxData { x: firefly::data::store::DataStore::dense(x), labels, k }
}

#[test]
fn property_sweep_random_shapes_all_models() {
    let _guard = path_lock();
    let mut rng = Rng::new(2024);

    // logistic: (n, d) shapes hitting every lane remainder class, with
    // untuned and MAP-style anchors
    for &(n, d) in &[(1usize, 1usize), (5, 3), (8, 8), (9, 4), (16, 7), (33, 12), (129, 5)] {
        let data = Arc::new(synth::synth_mnist(n, d, n as u64));
        let mut m = LogisticJJ::new(data, 1.5);
        check_model(&m, &mut rng, &format!("logistic n={n} d={d} untuned"));
        let anchor: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        m.tune_anchors_map(&anchor);
        check_model(&m, &mut rng, &format!("logistic n={n} d={d} tuned"));
    }

    // softmax: K sweep (the lane-major logits buffer is K-dependent)
    for &(n, d, k) in &[(7usize, 3usize, 2usize), (40, 6, 3), (65, 5, 5)] {
        let data = Arc::new(synth_softmax_k(n, d, k, (n * k) as u64));
        let m = SoftmaxBohning::new(data);
        check_model(&m, &mut rng, &format!("softmax n={n} d={d} k={k}"));
    }

    // robust: tuned anchors exercise the tangent math per datum
    for &(n, d) in &[(3usize, 2usize), (24, 8), (100, 10)] {
        let data = Arc::new(synth::synth_opv(n, d, n as u64));
        let mut m = RobustT::new(data, 4.0, 0.8);
        check_model(&m, &mut rng, &format!("robust n={n} d={d} untuned"));
        let anchor: Vec<f64> = (0..d).map(|_| rng.normal() * 0.4).collect();
        m.tune_anchors_map(&anchor);
        check_model(&m, &mut rng, &format!("robust n={n} d={d} tuned"));
    }
}

#[test]
fn batch_values_match_independent_oracles_bitwise() {
    let _guard = path_lock();
    let mut rng = Rng::new(7);

    let logistic = LogisticJJ::new(Arc::new(synth::synth_mnist(37, 6, 1)), 1.5);
    let robust = RobustT::new(Arc::new(synth::synth_opv(41, 5, 2)), 4.0, 0.8);
    let softmax = SoftmaxBohning::new(Arc::new(synth::synth_cifar3(29, 8, 3)));

    for path in [KernelPath::Scalar, KernelPath::Fast] {
        let theta: Vec<f64> = (0..logistic.dim()).map(|_| rng.normal()).collect();
        let idx: Vec<u32> = (0..logistic.n() as u32).collect();
        let out = eval_batch(&logistic, &theta, &idx, path);
        let mut rows = logistic.data.x.new_cache();
        for (i, &n) in idx.iter().enumerate() {
            let (ll, lb) = logistic_oracle(&logistic, &theta, n as usize, &mut rows);
            assert_eq!(out.ll[i].to_bits(), ll.to_bits(), "logistic ll oracle n={n}");
            assert_eq!(out.lb[i].to_bits(), lb.to_bits(), "logistic lb oracle n={n}");
        }

        let theta: Vec<f64> = (0..robust.dim()).map(|_| rng.normal() * 0.5).collect();
        let idx: Vec<u32> = (0..robust.n() as u32).collect();
        let out = eval_batch(&robust, &theta, &idx, path);
        let mut rows = robust.data.x.new_cache();
        for (i, &n) in idx.iter().enumerate() {
            let (ll, lb) = robust_oracle(&robust, &theta, n as usize, &mut rows);
            assert_eq!(out.ll[i].to_bits(), ll.to_bits(), "robust ll oracle n={n}");
            assert_eq!(out.lb[i].to_bits(), lb.to_bits(), "robust lb oracle n={n}");
        }

        let theta: Vec<f64> = (0..softmax.dim()).map(|_| rng.normal() * 0.3).collect();
        let idx: Vec<u32> = (0..softmax.n() as u32).collect();
        let out = eval_batch(&softmax, &theta, &idx, path);
        let mut rows = softmax.data.x.new_cache();
        let mut eta = vec![0.0; 3];
        for (i, &n) in idx.iter().enumerate() {
            let ll = softmax_ll_oracle(&softmax, &theta, n as usize, &mut rows, &mut eta);
            assert_eq!(out.ll[i].to_bits(), ll.to_bits(), "softmax ll oracle n={n}");
        }
    }
    set_kernel_path(KernelPath::Fast);
}

#[test]
fn block_store_batches_match_dense_bitwise_under_tiny_caches() {
    let _guard = path_lock();
    let mut rng = Rng::new(31);
    for &(n, d, rpb, budget) in &[(33usize, 5usize, 4usize, 8usize), (70, 9, 7, 14), (129, 6, 16, 32)]
    {
        let path = tmp(&format!("kern_{n}x{d}.fbin"));
        write_fbin(&path, &AnyData::Logistic(synth::synth_mnist(n, d, 77))).unwrap();
        let dense = LogisticJJ::new(Arc::new(synth::synth_mnist(n, d, 77)), 1.5);
        let cache = BlockCacheConfig { rows_per_block: rpb, cached_rows: budget };
        let blocked = match open_fbin(&path, cache).unwrap() {
            AnyData::Logistic(l) => LogisticJJ::new(Arc::new(l), 1.5),
            other => panic!("wrong kind {}", other.kind_name()),
        };
        let theta: Vec<f64> = (0..dense.dim()).map(|_| rng.normal()).collect();
        for idx in index_sets(n, &mut rng) {
            let a = eval_batch(&dense, &theta, &idx, KernelPath::Fast);
            let b = eval_batch(&blocked, &theta, &idx, KernelPath::Fast);
            assert_bits(&a.ll, &b.ll, "dense vs block ll");
            assert_bits(&a.lb, &b.lb, "dense vs block lb");
            assert_bits(&a.gp, &b.gp, "dense vs block pseudo grad");
            assert_bits(&a.gl, &b.gl, "dense vs block lik grad");
            assert_eq!(a.bp.to_bits(), b.bp.to_bits(), "dense vs block bound product");
        }
        let _ = std::fs::remove_file(path);
    }
}

#[test]
fn bound_product_batch_tracks_collapsed_product() {
    let _guard = path_lock();
    set_kernel_path(KernelPath::Fast);
    let mut rng = Rng::new(44);

    let mut logistic = LogisticJJ::new(Arc::new(synth::synth_mnist(120, 7, 3)), 1.5);
    let anchor: Vec<f64> = (0..7).map(|_| rng.normal() * 0.5).collect();
    logistic.tune_anchors_map(&anchor);
    let mut robust = RobustT::new(Arc::new(synth::synth_opv(90, 6, 4)), 4.0, 0.8);
    let anchor: Vec<f64> = (0..6).map(|_| rng.normal() * 0.3).collect();
    robust.tune_anchors_map(&anchor);

    for m in [&logistic as &dyn ModelBound, &robust as &dyn ModelBound] {
        let mut sc = m.new_scratch();
        let idx: Vec<u32> = (0..m.n() as u32).collect();
        for _ in 0..10 {
            let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.6).collect();
            let batch = m.log_bound_product_batch(&theta, &idx, &mut sc);
            let collapsed = m.log_bound_product(&theta, &mut sc);
            // the collapsed quadratic ignores the lb <= ll clamp, so they
            // agree only where the bound is genuinely below the likelihood
            // — which tuned anchors give almost everywhere; keep a loose
            // relative tolerance to absorb the association difference
            assert!(
                (batch - collapsed).abs() <= 1e-6 * (1.0 + collapsed.abs()),
                "bound product {batch} vs collapsed {collapsed}"
            );
        }
    }
}

fn assert_chains_byte_identical(a: &ChainResult, b: &ChainResult, label: &str) {
    assert_eq!(a.logpost_joint.len(), b.logpost_joint.len(), "{label}: iteration counts");
    for (i, (x, y)) in a.logpost_joint.iter().zip(&b.logpost_joint).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: logpost differs at iter {i}");
    }
    assert_eq!(a.bright, b.bright, "{label}: bright trajectories");
    assert_eq!(a.queries_per_iter, b.queries_per_iter, "{label}: query accounting");
    assert_eq!(a.theta_trace.n_rows(), b.theta_trace.n_rows(), "{label}: trace rows");
    for i in 0..a.theta_trace.n_rows() {
        for (x, y) in a.theta_trace.row(i).iter().zip(b.theta_trace.row(i)) {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: theta differs at row {i}");
        }
    }
    assert_eq!(a.accepted, b.accepted, "{label}: accepts");
    assert_eq!(a.z_brightened, b.z_brightened, "{label}: z brightened");
    assert_eq!(a.z_darkened, b.z_darkened, "{label}: z darkened");
}

fn run_with_path(cfg: &ExperimentConfig, path: KernelPath) -> Vec<ChainResult> {
    set_kernel_path(path);
    run_experiment(cfg).expect("run experiment").chains
}

#[test]
fn full_chains_scalar_vs_fast_identical_across_backends_and_stores() {
    let _guard = path_lock();
    // 3 paper workloads × {cpu, parcpu} × {dense, block}: scalar and fast
    // kernel paths must give byte-identical chains in every cell. The fast
    // results are then cross-compared between cells: dense↔block always,
    // cpu↔parcpu for the value-driven samplers (rwmh, slice). The MALA
    // chain reads gradients through the backends, whose shard tilings
    // differ, so cpu↔parcpu softmax agreement is tolerance-level by design
    // (see `rust/src/runtime/par_backend.rs`) and not asserted here.
    let cases: [(Task, Algorithm, usize, usize, usize, usize, u64, bool); 3] = [
        (Task::LogisticMnist, Algorithm::MapTunedFlyMc, 300, 80, 20, 40, 13, true),
        (Task::SoftmaxCifar, Algorithm::MapTunedFlyMc, 120, 40, 10, 30, 17, false),
        (Task::RobustOpv, Algorithm::UntunedFlyMc, 250, 50, 10, 0, 19, true),
    ];
    for (task, algorithm, n, iters, burnin, map_steps, seed, cross_backend) in cases {
        let mut fast_cells: Vec<(String, Vec<ChainResult>)> = Vec::new();
        for backend in [Backend::Cpu, Backend::ParCpu] {
            for block in [false, true] {
                let mut cfg = ExperimentConfig {
                    task,
                    algorithm,
                    n_data: Some(n),
                    iters,
                    burnin,
                    map_steps,
                    seed,
                    backend,
                    ..Default::default()
                };
                if backend == Backend::ParCpu {
                    cfg.threads = 3;
                }
                let file = tmp(&format!("{task:?}_{backend:?}_{block}.fbin"));
                if block {
                    write_fbin(&file, &synth_dataset(task, n, seed)).expect("write .fbin");
                    cfg.data_path = Some(file.clone());
                    cfg.cache_rows = n / 4; // far below N: constant eviction
                }
                let scalar = run_with_path(&cfg, KernelPath::Scalar);
                let fast = run_with_path(&cfg, KernelPath::Fast);
                assert_eq!(scalar.len(), fast.len());
                for (a, b) in scalar.iter().zip(&fast) {
                    assert_chains_byte_identical(
                        a,
                        b,
                        &format!("{task:?}/{backend:?}/block={block}: scalar vs fast"),
                    );
                }
                if block {
                    let _ = std::fs::remove_file(&file);
                }
                fast_cells.push((format!("{backend:?}/block={block}"), fast));
            }
        }
        // cells are [cpu/dense, cpu/block, parcpu/dense, parcpu/block]:
        // dense↔block within each backend always; cpu↔parcpu when the
        // sampler is value-driven (transitively pins all four cells)
        let mut pairs = vec![(0usize, 1usize), (2, 3)];
        if cross_backend {
            pairs.push((0, 2));
        }
        for (i, j) in pairs {
            let (la, ca) = &fast_cells[i];
            let (lb, cb) = &fast_cells[j];
            for (a, b) in ca.iter().zip(cb) {
                assert_chains_byte_identical(a, b, &format!("{task:?}: {la} vs {lb}"));
            }
        }
    }
}
