//! End-to-end distributed engine integration (DESIGN.md §Distribution).
//!
//! The `dist` backend must be bit-identical to the serial `cpu` backend
//! through full chains — θ-traces, acceptances, z-flips, and likelihood
//! query counters — at any worker count, on all three paper workloads, and
//! across the failure path: a connection dropped mid-chain, and a worker
//! killed and restarted between evaluations. Malformed inputs (corrupt
//! frames, mismatched shard manifests) must be rejected cleanly, never
//! folded into a chain.

use std::sync::Arc;

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::data::fbin::write_fbin;
use firefly::data::shard::{split_fbin, ShardManifest};
use firefly::data::store::BlockCacheConfig;
use firefly::engine::{run_experiment, synth_dataset};
use firefly::metrics::Counters;
use firefly::models::ModelBound;
use firefly::net::worker::{spawn_worker, FaultPlan, WorkerHandle, WorkerState};
use firefly::runtime::{BatchEval, CpuBackend, DistBackend, DistOptions};
use firefly::util::Rng;

fn cfg(task: Task, backend: Backend) -> ExperimentConfig {
    ExperimentConfig {
        task,
        algorithm: Algorithm::MapTunedFlyMc,
        backend,
        n_data: Some(240),
        iters: 40,
        burnin: 10,
        map_steps: 40,
        record_every: 0,
        seed: 7,
        ..Default::default()
    }
}

fn assert_chains_identical(a: &ExperimentConfig, b: &ExperimentConfig, label: &str) {
    let serial = run_experiment(a).unwrap();
    let dist = run_experiment(b).unwrap();
    assert_eq!(serial.chains.len(), dist.chains.len(), "{label}");
    for (s, d) in serial.chains.iter().zip(&dist.chains) {
        assert_eq!(s.seed, d.seed, "{label}");
        assert_eq!(s.logpost_joint, d.logpost_joint, "{label}: logpost");
        assert_eq!(s.theta_trace, d.theta_trace, "{label}: theta trace");
        assert_eq!(s.bright, d.bright, "{label}: bright trajectory");
        assert_eq!(s.accepted, d.accepted, "{label}: acceptances");
        assert_eq!(
            (s.z_brightened, s.z_darkened),
            (d.z_brightened, d.z_darkened),
            "{label}: z-flips"
        );
        // the paper's cost unit: metering may not move when the work does
        assert_eq!(s.queries_per_iter, d.queries_per_iter, "{label}: queries/iter");
        assert_eq!(s.final_counters, d.final_counters, "{label}: counter totals");
        assert!(s.logpost_joint.iter().all(|l| l.is_finite()), "{label}");
    }
}

#[test]
fn dist_chains_byte_identical_on_all_three_workloads() {
    // logistic + RW-MH, softmax + MALA (the gradient path), robust + slice
    for task in [Task::LogisticMnist, Task::SoftmaxCifar, Task::RobustOpv] {
        let serial = cfg(task, Backend::Cpu);
        for workers in [1usize, 2, 4] {
            let mut dist = cfg(task, Backend::Dist);
            dist.dist_workers = workers;
            assert_chains_identical(&serial, &dist, &format!("{task:?} x{workers}"));
        }
    }
}

#[test]
fn untuned_flymc_dist_chain_matches_serial() {
    // the untuned variant exercises the no-anchor Hello path (spec.anchor
    // empty; workers build from xi_const alone)
    let mut serial = cfg(Task::LogisticMnist, Backend::Cpu);
    serial.algorithm = Algorithm::UntunedFlyMc;
    let mut dist = cfg(Task::LogisticMnist, Backend::Dist);
    dist.algorithm = Algorithm::UntunedFlyMc;
    dist.dist_workers = 3; // uneven split of 240
    assert_chains_identical(&serial, &dist, "untuned x3");
}

/// Temp path helper unique to this test binary's process.
fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("firefly_dist_it_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Spawn shard workers from `.fbin` shard files the way `firefly worker`
/// does (manifest-validated, model built on first Hello), with an optional
/// fault plan on one worker.
fn spawn_shard_workers(
    manifest: &ShardManifest,
    manifest_path: &str,
    fault_on: Option<(usize, FaultPlan)>,
) -> Vec<WorkerHandle> {
    manifest
        .shards
        .iter()
        .enumerate()
        .map(|(i, entry)| {
            let data = firefly::data::shard::open_shard(
                manifest,
                manifest_path,
                i,
                BlockCacheConfig::default(),
            )
            .unwrap();
            let state = WorkerState::from_data(data, entry.start, entry.end, manifest.n);
            let fault = fault_on.and_then(|(fi, f)| (fi == i).then_some(f));
            spawn_worker(state, "127.0.0.1:0", fault).unwrap()
        })
        .collect()
}

#[test]
fn connection_dropped_mid_chain_reconnects_and_stays_identical() {
    // A worker that deterministically severs its connection every 15
    // requests forces the coordinator through reconnect + re-Hello + resend
    // many times per chain. The finished chain must not differ in a single
    // bit from the uninterrupted serial run.
    let n = 240;
    let serial_cfg = cfg(Task::LogisticMnist, Backend::Cpu);
    let src = tmp("drop.fbin");
    write_fbin(&src, &synth_dataset(Task::LogisticMnist, n, serial_cfg.seed)).unwrap();
    let out_dir = tmp("drop_shards");
    let (manifest, manifest_path) =
        split_fbin(&src, &out_dir, 2, BlockCacheConfig::default()).unwrap();
    let workers =
        spawn_shard_workers(&manifest, &manifest_path, Some((0, FaultPlan { drop_conn_after: 15 })));

    let mut dist_cfg = cfg(Task::LogisticMnist, Backend::Dist);
    dist_cfg.dist_connect = workers.iter().map(|w| w.addr.to_string()).collect();
    dist_cfg.dist_manifest = Some(manifest_path.clone());
    dist_cfg.dist_retry_backoff_ms = 20; // keep the forced retries fast
    assert_chains_identical(&serial_cfg, &dist_cfg, "conn-drop x2");

    drop(workers);
    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn killed_worker_restarted_on_its_port_resumes_statelessly() {
    // Segmented evaluation against CpuBackend: kill one worker between
    // batches, restart it on the same port from the same shard file, and
    // the next evaluations must come back byte-identical — the restarted
    // worker rebuilds all of its state from the coordinator's re-Hello.
    let n = 200;
    let seed = 13;
    let src = tmp("kill.fbin");
    write_fbin(&src, &synth_dataset(Task::LogisticMnist, n, seed)).unwrap();
    let out_dir = tmp("kill_shards");
    let (manifest, manifest_path) =
        split_fbin(&src, &out_dir, 2, BlockCacheConfig::default()).unwrap();
    let mut workers = spawn_shard_workers(&manifest, &manifest_path, None);

    // the exact model the engine would build for this dataset
    let data = synth_dataset(Task::LogisticMnist, n, seed);
    let model: Arc<dyn ModelBound> = match data {
        firefly::data::AnyData::Logistic(d) => {
            Arc::new(firefly::models::LogisticJJ::new(Arc::new(d), 1.5))
        }
        _ => unreachable!(),
    };
    let mut cpu = CpuBackend::new(model.clone(), Counters::new());
    let opts = DistOptions {
        connect: workers.iter().map(|w| w.addr.to_string()).collect(),
        manifest: Some(manifest_path.clone()),
        retry_backoff_ms: 20,
        ..DistOptions::default()
    };
    let mut dist = DistBackend::new(model.clone(), Counters::new(), &opts).unwrap();

    let mut rng = Rng::new(99);
    let dim = model.dim();
    let (mut ll_a, mut lb_a) = (Vec::new(), Vec::new());
    let (mut ll_b, mut lb_b) = (Vec::new(), Vec::new());
    let mut eval_round = |cpu: &mut CpuBackend,
                          dist: &mut DistBackend,
                          rng: &mut Rng,
                          ll_a: &mut Vec<f64>,
                          lb_a: &mut Vec<f64>,
                          ll_b: &mut Vec<f64>,
                          lb_b: &mut Vec<f64>| {
        let theta: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
        let idx: Vec<u32> = (0..120).map(|_| (rng.next_u64() % n as u64) as u32).collect();
        cpu.eval(&theta, &idx, ll_a, lb_a);
        dist.eval(&theta, &idx, ll_b, lb_b);
        assert_eq!(ll_a, ll_b);
        assert_eq!(lb_a, lb_b);
    };

    for _ in 0..3 {
        eval_round(&mut cpu, &mut dist, &mut rng, &mut ll_a, &mut lb_a, &mut ll_b, &mut lb_b);
    }

    // kill worker 0 and restart it on the very port it vacated, from disk
    let addr = workers[0].addr;
    workers[0].stop();
    let entry = &manifest.shards[0];
    let data =
        firefly::data::shard::open_shard(&manifest, &manifest_path, 0, BlockCacheConfig::default())
            .unwrap();
    let state = WorkerState::from_data(data, entry.start, entry.end, manifest.n);
    workers[0] = spawn_worker(state, &addr.to_string(), None).unwrap();

    for _ in 0..3 {
        eval_round(&mut cpu, &mut dist, &mut rng, &mut ll_a, &mut lb_a, &mut ll_b, &mut lb_b);
    }
    // the coordinator went through the reconnect path at least once and the
    // query metering never double-counted a retried request
    assert!(opts.wire.reconnects() >= 1, "reconnects: {}", opts.wire.reconnects());
    assert_eq!(cpu.counters().totals(), dist.counters().totals());

    drop(workers);
    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn corrupted_frame_closes_the_connection_cleanly() {
    // A frame whose checksum trailer does not match its payload must end
    // that connection (clean EOF for the peer) without taking the worker
    // down: the next connection gets served normally.
    use std::io::{Read, Write};

    let n = 60;
    let data = synth_dataset(Task::LogisticMnist, n, 3);
    let model: Arc<dyn ModelBound> = match data {
        firefly::data::AnyData::Logistic(d) => {
            Arc::new(firefly::models::LogisticJJ::new(Arc::new(d), 1.5))
        }
        _ => unreachable!(),
    };
    let shard = model.shard_model(0, n).unwrap();
    let state = WorkerState::in_process(shard, 0, n, n);
    let worker = spawn_worker(state, "127.0.0.1:0", None).unwrap();

    let mut bad = std::net::TcpStream::connect(worker.addr).unwrap();
    let mut frame = Vec::new();
    frame.extend_from_slice(&8u32.to_le_bytes()); // length: 8-byte payload
    frame.extend_from_slice(&[0x5A; 8]); // payload
    frame.extend_from_slice(&[0u8; 8]); // checksum trailer: wrong on purpose
    bad.write_all(&frame).unwrap();
    bad.flush().unwrap();
    let mut sink = Vec::new();
    let got = bad.read_to_end(&mut sink).unwrap();
    assert_eq!(got, 0, "worker must close a corrupt connection without replying");

    // the worker survives and serves a real coordinator afterwards
    let opts = DistOptions {
        connect: vec![worker.addr.to_string()],
        ..DistOptions::default()
    };
    let mut dist = DistBackend::new(model.clone(), Counters::new(), &opts).unwrap();
    let mut cpu = CpuBackend::new(model.clone(), Counters::new());
    let theta = vec![0.05; model.dim()];
    let idx: Vec<u32> = (0..n as u32).collect();
    let (mut ll_a, mut lb_a) = (Vec::new(), Vec::new());
    let (mut ll_b, mut lb_b) = (Vec::new(), Vec::new());
    cpu.eval(&theta, &idx, &mut ll_a, &mut lb_a);
    dist.eval(&theta, &idx, &mut ll_b, &mut lb_b);
    assert_eq!(ll_a, ll_b);
    assert_eq!(lb_a, lb_b);
}

#[test]
fn mismatched_manifest_is_rejected_at_startup() {
    // Coordinator side: a manifest whose N disagrees with the model must
    // refuse to build the backend (before any chain state exists).
    let src = tmp("mismatch.fbin");
    write_fbin(&src, &synth_dataset(Task::LogisticMnist, 160, 5)).unwrap();
    let out_dir = tmp("mismatch_shards");
    let (_, manifest_path) = split_fbin(&src, &out_dir, 2, BlockCacheConfig::default()).unwrap();

    let data = synth_dataset(Task::LogisticMnist, 200, 5); // N = 200 != 160
    let model: Arc<dyn ModelBound> = match data {
        firefly::data::AnyData::Logistic(d) => {
            Arc::new(firefly::models::LogisticJJ::new(Arc::new(d), 1.5))
        }
        _ => unreachable!(),
    };
    let opts = DistOptions {
        workers: 2,
        manifest: Some(manifest_path.clone()),
        ..DistOptions::default()
    };
    let err = match DistBackend::new(model, Counters::new(), &opts) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("a mismatched manifest must not build a backend"),
    };
    assert!(err.contains("does not match the model"), "{err}");

    // Worker side: a shard file that no longer hashes to the manifest's
    // checksum is refused before a single row is served.
    let manifest = ShardManifest::load(&manifest_path).unwrap();
    let shard_file = manifest.shard_path(&manifest_path, 0);
    let mut bytes = std::fs::read(&shard_file).unwrap();
    let at = bytes.len() - 1;
    bytes[at] ^= 0x10;
    std::fs::write(&shard_file, &bytes).unwrap();
    let err = firefly::data::shard::open_shard(
        &manifest,
        &manifest_path,
        0,
        BlockCacheConfig::default(),
    )
    .unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");

    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_dir_all(&out_dir);
}
