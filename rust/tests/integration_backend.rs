//! Cross-layer numerics: the XLA backend (executing the AOT artifacts that
//! came from the Pallas kernels through `make artifacts`) must agree with
//! the pure-Rust CpuBackend to f64 tolerance, for all three models, across
//! batch sizes that exercise padding and multi-chunk execution.
//!
//! Requires `artifacts/` (run `make artifacts` first); each test is a no-op
//! with a notice if the artifacts are missing.

use std::sync::Arc;

use firefly::data::synth;
use firefly::metrics::Counters;
use firefly::models::{LogisticJJ, ModelBound, RobustT, SoftmaxBohning};
use firefly::runtime::{BatchEval, CpuBackend, XlaBackend, XlaSource};
use firefly::util::Rng;

fn artifacts_available() -> bool {
    // the stub backend (default build) errors on construction, so artifacts
    // on disk are only usable when the real PJRT backend is compiled in
    cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.txt").exists()
}

fn compare_backends(source: Arc<dyn XlaSource>, theta_scale: f64, seed: u64) {
    let dim = source.dim();
    let n = source.n();
    let mut rng = Rng::new(seed);
    let theta: Vec<f64> = (0..dim).map(|_| rng.normal() * theta_scale).collect();

    let mut cpu = CpuBackend::new(source.clone().as_model_bound(), Counters::new());
    let mut xla = XlaBackend::new(source, Counters::new(), "artifacts").expect("artifact lookup");

    // batch sizes: tiny (padding-dominated), bucket-boundary, multi-chunk
    for &bs in &[1usize, 3, 255, 256, 257, 300] {
        let idx: Vec<u32> = (0..bs).map(|_| rng.below(n) as u32).collect();
        let (mut cll, mut clb) = (Vec::new(), Vec::new());
        let (mut xll, mut xlb) = (Vec::new(), Vec::new());
        let mut cgrad = vec![0.0; dim];
        let mut xgrad = vec![0.0; dim];
        cpu.eval_pseudo_grad(&theta, &idx, &mut cll, &mut clb, &mut cgrad);
        xla.eval_pseudo_grad(&theta, &idx, &mut xll, &mut xlb, &mut xgrad);
        assert_eq!(xll.len(), bs);
        for i in 0..bs {
            assert!(
                (cll[i] - xll[i]).abs() < 1e-9 * (1.0 + cll[i].abs()),
                "ll mismatch bs={bs} i={i}: cpu {} xla {}",
                cll[i],
                xll[i]
            );
            assert!(
                (clb[i] - xlb[i]).abs() < 1e-9 * (1.0 + clb[i].abs()),
                "lb mismatch bs={bs} i={i}: cpu {} xla {}",
                clb[i],
                xlb[i]
            );
        }
        for j in 0..dim {
            assert!(
                (cgrad[j] - xgrad[j]).abs() < 1e-5 * (1.0 + cgrad[j].abs()),
                "pseudo-grad mismatch bs={bs} j={j}: cpu {} xla {}",
                cgrad[j],
                xgrad[j]
            );
        }

        // lik-grad path
        let mut cll2 = Vec::new();
        let mut xll2 = Vec::new();
        let mut cg2 = vec![0.0; dim];
        let mut xg2 = vec![0.0; dim];
        cpu.eval_lik_grad(&theta, &idx, &mut cll2, &mut cg2);
        xla.eval_lik_grad(&theta, &idx, &mut xll2, &mut xg2);
        for j in 0..dim {
            assert!(
                (cg2[j] - xg2[j]).abs() < 1e-5 * (1.0 + cg2[j].abs()),
                "lik-grad mismatch bs={bs} j={j}"
            );
        }
    }
}

#[test]
fn xla_matches_cpu_logistic_d51() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let data = Arc::new(synth::synth_mnist(600, 50, 7));
    let mut model = LogisticJJ::new(data, 1.5);
    // non-trivial anchors
    let mut rng = Rng::new(1);
    let anchor: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.2).collect();
    model.tune_anchors_map(&anchor);
    compare_backends(Arc::new(model), 0.5, 11);
}

#[test]
fn xla_matches_cpu_softmax_k3_d256() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let data = Arc::new(synth::synth_cifar3(500, 256, 8));
    let mut model = SoftmaxBohning::new(data);
    let mut rng = Rng::new(2);
    let anchor: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.1).collect();
    model.tune_anchors_map(&anchor);
    compare_backends(Arc::new(model), 0.2, 12);
}

#[test]
fn xla_matches_cpu_robust_d57_with_sigma_rescale() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let data = Arc::new(synth::synth_opv(700, 57, 9));
    // sigma != 1 exercises the rescaling identity against the sigma=1 artifact
    let mut model = RobustT::new(data, 4.0, 0.7);
    let mut rng = Rng::new(3);
    let anchor: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.3).collect();
    model.tune_anchors_map(&anchor);
    compare_backends(Arc::new(model), 0.4, 13);
}

#[test]
fn xla_backend_pads_and_buckets() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let data = Arc::new(synth::synth_mnist(400, 50, 17));
    let model = Arc::new(LogisticJJ::new(data, 1.5));
    let counters = Counters::new();
    let mut xla = XlaBackend::new(model.clone(), counters.clone(), "artifacts").unwrap();
    assert!(xla.available_buckets().contains(&256));
    let theta = vec![0.1; model.dim()];
    let (mut ll, mut lb) = (Vec::new(), Vec::new());
    xla.eval(&theta, &[1, 2, 3], &mut ll, &mut lb);
    assert_eq!(ll.len(), 3);
    assert_eq!(counters.lik_queries(), 3);
    assert_eq!(counters.padded_lanes(), 253); // padded up to the 256 bucket
    assert_eq!(counters.xla_executions(), 1);
}

#[test]
fn missing_artifact_shape_is_a_clean_error() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // d=13 logistic has no artifact
    let data = Arc::new(synth::synth_mnist(50, 12, 1)); // d = 13 with bias
    let model = Arc::new(LogisticJJ::new(data, 1.5));
    let msg = match XlaBackend::new(model, Counters::new(), "artifacts") {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => format!("{e:#}"),
    };
    assert!(msg.contains("no artifact"), "{msg}");
}
