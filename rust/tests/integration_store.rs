//! End-to-end acceptance tests for the unified `DataStore` layer: a chain
//! sampled from a `.fbin` `BlockStore` — through the real engine, including
//! MAP tuning, bound collapse, z-resampling and both CPU backends — must be
//! **byte-identical** to the same chain over the resident `DenseStore`,
//! even when the block cache is far smaller than the dataset (constant
//! eviction). Format-level round-trip, corruption and truncation cases live
//! in `rust/src/data/fbin.rs`; the zero-allocation guarantee for block-
//! cached sampling lives in the `integration_hotpath*` binaries.

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::data::fbin::{open_fbin, write_fbin};
use firefly::data::store::BlockCacheConfig;
use firefly::data::AnyData;
use firefly::engine::{run_experiment, synth_dataset, ChainResult};

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("firefly_itstore_{}_{name}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn assert_chains_byte_identical(dense: &ChainResult, block: &ChainResult, label: &str) {
    assert_eq!(
        dense.logpost_joint.len(),
        block.logpost_joint.len(),
        "{label}: iteration counts differ"
    );
    for (i, (a, b)) in dense.logpost_joint.iter().zip(&block.logpost_joint).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: logpost differs at iter {i}");
    }
    assert_eq!(dense.bright, block.bright, "{label}: bright trajectories differ");
    assert_eq!(
        dense.queries_per_iter, block.queries_per_iter,
        "{label}: query accounting differs"
    );
    assert_eq!(dense.theta_trace.n_rows(), block.theta_trace.n_rows(), "{label}");
    for i in 0..dense.theta_trace.n_rows() {
        for (a, b) in dense.theta_trace.row(i).iter().zip(block.theta_trace.row(i)) {
            assert_eq!(a.to_bits(), b.to_bits(), "{label}: theta differs at row {i}");
        }
    }
    assert_eq!(dense.accepted, block.accepted, "{label}");
    assert_eq!(dense.z_brightened, block.z_brightened, "{label}");
    assert_eq!(dense.z_darkened, block.z_darkened, "{label}");
}

/// One experiment twice — dense synth vs the same data via `.fbin` with a
/// deliberately tiny cache — and byte-compare the chains.
fn run_dense_vs_block(mut cfg: ExperimentConfig, path: &str, cache_rows: usize) {
    let n = cfg.n_data.expect("test configs pin n");
    let data = synth_dataset(cfg.task, n, cfg.seed);
    write_fbin(path, &data).expect("write .fbin");

    let dense = run_experiment(&cfg).expect("dense run");
    cfg.data_path = Some(path.to_string());
    cfg.cache_rows = cache_rows;
    let block = run_experiment(&cfg).expect("block run");

    assert!(cache_rows < n, "test must force eviction");
    for (d, b) in dense.chains.iter().zip(&block.chains) {
        assert_chains_byte_identical(d, b, &format!("{:?}/{:?}", cfg.task, cfg.backend));
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn logistic_map_tuned_block_chain_matches_dense_on_cpu() {
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(400),
        iters: 120,
        burnin: 30,
        map_steps: 60,
        record_every: 0,
        seed: 5,
        ..Default::default()
    };
    run_dense_vs_block(cfg, &tmp("logistic_cpu.fbin"), 64);
}

#[test]
fn logistic_block_chain_matches_dense_on_parcpu() {
    // the sharded backend reads through per-worker-group caches — identical
    // bits regardless of cache topology
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(500),
        iters: 100,
        burnin: 20,
        backend: Backend::ParCpu,
        threads: 3,
        record_every: 0,
        seed: 11,
        ..Default::default()
    };
    run_dense_vs_block(cfg, &tmp("logistic_parcpu.fbin"), 48);
}

#[test]
fn softmax_and_robust_block_chains_match_dense() {
    let softmax = ExperimentConfig {
        task: Task::SoftmaxCifar,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(150),
        iters: 50,
        burnin: 10,
        record_every: 0,
        seed: 7,
        ..Default::default()
    };
    run_dense_vs_block(softmax, &tmp("softmax.fbin"), 32);

    let robust = ExperimentConfig {
        task: Task::RobustOpv,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(300),
        iters: 50,
        burnin: 10,
        record_every: 0,
        seed: 9,
        ..Default::default()
    };
    run_dense_vs_block(robust, &tmp("robust.fbin"), 40);
}

#[test]
fn multi_replica_block_chains_match_dense() {
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(300),
        iters: 60,
        burnin: 20,
        chains: 3,
        record_every: 0,
        seed: 21,
        ..Default::default()
    };
    run_dense_vs_block(cfg, &tmp("replicas.fbin"), 50);
}

#[test]
fn mismatched_task_and_label_kind_is_rejected() {
    let path = tmp("mismatch.fbin");
    let data = synth_dataset(Task::RobustOpv, 60, 1);
    write_fbin(&path, &data).unwrap();
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        n_data: Some(60),
        iters: 10,
        burnin: 2,
        data_path: Some(path.clone()),
        ..Default::default()
    };
    let err = run_experiment(&cfg).unwrap_err().to_string();
    assert!(err.contains("regression"), "{err}");
    assert!(err.contains("LogisticMnist"), "{err}");
    let _ = std::fs::remove_file(path);
}

#[test]
fn fbin_random_shapes_roundtrip_bitwise_under_tiny_caches() {
    // Property-style sweep: assorted (n, d, cache) shapes, including caches
    // of a single block and block sizes that do not divide n.
    use firefly::util::Rng;
    let mut rng = Rng::new(77);
    for (case, &(n, d)) in [(33usize, 3usize), (64, 8), (129, 5), (200, 12)].iter().enumerate() {
        let path = tmp(&format!("prop_{case}.fbin"));
        let data = AnyData::Regression(firefly::data::synth::synth_opv(n, d, case as u64));
        write_fbin(&path, &data).unwrap();
        let dense = match &data {
            AnyData::Regression(r) => r,
            _ => unreachable!(),
        };
        let dm = dense.x.as_dense().unwrap();
        for &(rpb, budget) in &[(7usize, 7usize), (16, 32), (64, 64)] {
            let cache = BlockCacheConfig { rows_per_block: rpb, cached_rows: budget };
            let got = match open_fbin(&path, cache).unwrap() {
                AnyData::Regression(r) => r,
                other => panic!("wrong kind {}", other.kind_name()),
            };
            let mut rc = got.x.new_cache();
            for _ in 0..4 * n {
                let i = rng.below(n);
                let row = got.x.row(i, &mut rc);
                for (a, b) in row.iter().zip(dm.row(i)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} d={d} rpb={rpb} row={i}");
                }
            }
            for (a, b) in got.y.iter().zip(&dense.y) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let _ = std::fs::remove_file(path);
    }
}
