//! End-to-end chain integration over the engine: every (task × algorithm ×
//! backend) combination runs, produces finite traces, and the FlyMC variants
//! query fewer likelihoods than regular MCMC. XLA-backed runs require
//! `make artifacts`.

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::engine::run_experiment;

fn artifacts_available() -> bool {
    // requires both the AOT artifacts on disk and the real PJRT backend
    // compiled in (the default build's stub errors on construction)
    cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.txt").exists()
}

fn cfg(task: Task, algorithm: Algorithm, backend: Backend, n: usize) -> ExperimentConfig {
    ExperimentConfig {
        task,
        algorithm,
        backend,
        n_data: Some(n),
        iters: 40,
        burnin: 15,
        map_steps: 40,
        record_every: 0,
        ..Default::default()
    }
}

#[test]
fn cpu_experiments_all_combinations() {
    for task in [Task::LogisticMnist, Task::RobustOpv] {
        for alg in [
            Algorithm::RegularMcmc,
            Algorithm::UntunedFlyMc,
            Algorithm::MapTunedFlyMc,
        ] {
            let res = run_experiment(&cfg(task, alg, Backend::Cpu, 400))
                .unwrap_or_else(|e| panic!("{task:?}/{alg:?}: {e:#}"));
            let row = res.table_row();
            assert!(row.avg_lik_queries_per_iter.is_finite());
            if alg == Algorithm::RegularMcmc && task == Task::LogisticMnist {
                assert!((row.avg_lik_queries_per_iter - 400.0).abs() < 1e-9);
            }
        }
    }
}

#[test]
fn xla_backend_runs_logistic_experiment_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // d must match an artifact: synth_mnist(_, 50) -> d=51
    let mut c = cfg(Task::LogisticMnist, Algorithm::MapTunedFlyMc, Backend::Xla, 500);
    c.iters = 25;
    c.burnin = 10;
    let res = run_experiment(&c).expect("xla experiment");
    let row = res.table_row();
    assert!(row.avg_lik_queries_per_iter < 500.0);
    assert!(res.chains[0].logpost_joint.iter().all(|l| l.is_finite()));
}

#[test]
fn xla_and_cpu_chains_are_statistically_consistent() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // identical seeds => identical chains (backends agree to f64 rounding;
    // the MH accept decisions compare the same numbers)
    let mut a = cfg(Task::LogisticMnist, Algorithm::UntunedFlyMc, Backend::Cpu, 600);
    a.iters = 30;
    let mut b = a.clone();
    b.backend = Backend::Xla;
    let ra = run_experiment(&a).unwrap();
    let rb = run_experiment(&b).unwrap();
    let la = &ra.chains[0].logpost_joint;
    let lb = &rb.chains[0].logpost_joint;
    assert_eq!(la.len(), lb.len());
    for (x, y) in la.iter().zip(lb) {
        assert!(
            (x - y).abs() < 1e-6 * (1.0 + x.abs()),
            "trace diverged: {x} vs {y}"
        );
    }
    assert_eq!(&ra.chains[0].bright, &rb.chains[0].bright);
}

#[test]
fn explicit_resampling_chain_runs() {
    let mut c = cfg(Task::LogisticMnist, Algorithm::UntunedFlyMc, Backend::Cpu, 300);
    c.explicit_resample = true;
    c.resample_fraction = 0.2;
    let res = run_experiment(&c).unwrap();
    // explicit: ~fraction * N queries per iter for the z-step + M for θ
    let q = res.table_row().avg_lik_queries_per_iter;
    assert!(q >= 60.0, "explicit resampling should cost ≥ fraction·N, got {q}");
}

#[test]
fn toy_task_fig2_style_run() {
    let c = cfg(Task::Toy, Algorithm::UntunedFlyMc, Backend::Cpu, 30);
    let res = run_experiment(&c).unwrap();
    assert_eq!(res.n_data, 30);
    assert!(res.chains[0].bright.iter().all(|&b| b <= 30));
}
