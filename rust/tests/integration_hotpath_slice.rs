//! Zero-allocation invariant for the slice-sampling hot path: steady-state
//! FlyMC iterations on the robust-regression task with univariate slice
//! sampling (the paper's OPV configuration, Table 1 rows 7–9) must perform
//! **zero** heap allocations on the serial CPU backend. The Laplace prior
//! is deliberately used so the base density takes the non-quadratic
//! fallback (prior + collapsed bound product as two calls), covering the
//! scratch-based `log_bound_product` path rather than the fused
//! `PackedQuadForm` one.
//!
//! Measured over BOTH stores: resident `DenseStore` and an out-of-core
//! `.fbin` `BlockStore` with a cache smaller than N (misses inside the
//! measured window must not allocate — DESIGN.md §Storage).
//!
//! This binary deliberately contains a SINGLE test: the allocator counter
//! is process-global, so a sibling test allocating concurrently would
//! corrupt the measurement window. Siblings: `integration_hotpath.rs`
//! (RW-MH + logistic) and `integration_hotpath_mala.rs` (MALA + softmax).

use std::sync::Arc;

use firefly::data::store::BlockCacheConfig;
use firefly::data::{synth, AnyData, RegressionData};
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{Laplace, ModelBound, Prior, RobustT};
use firefly::runtime::CpuBackend;
use firefly::samplers::{Sampler, SliceSampler};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn dataset(block: bool) -> RegressionData {
    let data = synth::synth_opv(400, 12, 9);
    if !block {
        return data;
    }
    let cache = BlockCacheConfig { rows_per_block: 16, cached_rows: 64 }; // << N=400
    match firefly::testing::fbin_roundtrip(&AnyData::Regression(data), cache) {
        AnyData::Regression(d) => d,
        other => panic!("wrong kind {}", other.kind_name()),
    }
}

#[test]
fn steady_state_slice_robust_iterations_allocate_nothing() {
    for block in [false, true] {
        let data = Arc::new(dataset(block));
        let model: Arc<dyn ModelBound> = Arc::new(RobustT::new(data, 4.0, 0.5));
        let prior: Arc<dyn Prior> = Arc::new(Laplace { b: 0.5 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(13);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut theta = theta0.clone();
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
        pp.init_z(&mut rng);
        let mut slice = SliceSampler::new(0.05).with_coords_per_iter(2);

        for _ in 0..100 {
            slice.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.1, &mut rng);
        }

        let allocs_before = ALLOC.allocations();
        let queries_before = counters.lik_queries();
        let misses_before = counters.data_cache_misses();
        let mut bright_sum: usize = 0;
        for _ in 0..300 {
            slice.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.1, &mut rng);
            bright_sum += pp.n_bright();
        }
        let allocs = ALLOC.allocations() - allocs_before;
        let queries = counters.lik_queries() - queries_before;

        // the window must have done real slice work (variable evals/update) ...
        assert!(queries > 0, "block={block}: no likelihood queries in the window");
        assert!(bright_sum > 0, "block={block}: degenerate chain, nothing ever bright");
        assert!(slice.mean_evals_per_step() >= 3.0);
        if block {
            let misses = counters.data_cache_misses() - misses_before;
            assert!(misses > 0, "block cache never missed (cache 64 < N=400)");
        }
        // ... with ZERO heap allocations
        assert_eq!(
            allocs, 0,
            "block={block}: steady-state slice+robust FlyMC iterations performed \
             {allocs} heap allocations (zero-alloc hot-path invariant, DESIGN.md \
             §Perf/§Storage)"
        );
    }
}
