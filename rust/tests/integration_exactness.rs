//! The paper's central claim, tested end-to-end: FlyMC leaves the *exact*
//! full-data posterior invariant — the marginal distribution of θ under the
//! augmented (θ, z) chain matches the distribution regular MCMC samples.
//!
//! We use a small logistic problem where both chains mix quickly, run long,
//! and compare posterior means / variances per component, plus the predictive
//! probability at a held-out point. Tolerances are set by the Monte-Carlo
//! error of the runs (seeds fixed; deterministic).

use std::sync::Arc;

use firefly::configx::{Algorithm, ExperimentConfig, Task};
use firefly::data::synth;
use firefly::engine::{build_chain, run_chain, ChainConfig};
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::runtime::CpuBackend;
use firefly::samplers::{RandomWalkMh, Target};
use firefly::util::Rng;

fn posterior_moments(trace: &firefly::diagnostics::TraceMatrix) -> (Vec<f64>, Vec<f64>) {
    let d = trace.dim();
    let t = trace.n_rows() as f64;
    let mut mean = vec![0.0; d];
    for row in trace.rows() {
        for j in 0..d {
            mean[j] += row[j] / t;
        }
    }
    let mut var = vec![0.0; d];
    for row in trace.rows() {
        for j in 0..d {
            var[j] += (row[j] - mean[j]) * (row[j] - mean[j]) / t;
        }
    }
    (mean, var)
}

#[test]
fn flymc_marginal_matches_regular_mcmc() {
    let base = ExperimentConfig {
        task: Task::Toy,
        n_data: Some(120),
        iters: 60_000,
        burnin: 5_000,
        prior_scale: Some(2.0),
        ..Default::default()
    };

    let run = |algorithm: Algorithm, seed: u64| {
        let mut cfg = base.clone();
        cfg.algorithm = algorithm;
        cfg.seed = 3; // same dataset for both
        let (model, prior, _, _) =
            firefly::engine::experiment::build_model(&cfg).expect("build model");
        let (target, theta0) =
            build_chain(&cfg, model, prior, seed).expect("build chain");
        let ccfg = ChainConfig {
            iters: cfg.iters,
            burnin: cfg.burnin,
            record_full_every: 0,
            thin: 5,
            q_dark_to_bright: 0.2,
            explicit_resample: false,
            resample_fraction: 0.1,
            seed,
            record_trace: true,
            ..Default::default()
        };
        run_chain(
            target,
            Box::new(RandomWalkMh::adaptive(0.1)),
            theta0,
            &ccfg,
        )
    };

    let regular = run(Algorithm::RegularMcmc, 101);
    let flymc = run(Algorithm::UntunedFlyMc, 202);

    let (rm, rv) = posterior_moments(&regular.theta_trace);
    let (fm, fv) = posterior_moments(&flymc.theta_trace);
    for j in 0..rm.len() {
        let scale = rv[j].sqrt();
        assert!(
            (rm[j] - fm[j]).abs() < 0.15 * scale + 0.02,
            "posterior mean mismatch at dim {j}: regular {} flymc {} (sd {scale})",
            rm[j],
            fm[j]
        );
        assert!(
            (rv[j] - fv[j]).abs() < 0.3 * rv[j] + 1e-4,
            "posterior var mismatch at dim {j}: regular {} flymc {}",
            rv[j],
            fv[j]
        );
    }
}

#[test]
fn one_dim_posterior_mean_matches_quadrature_all_z_schemes() {
    // Regression test for the once-per-point Alg-2 sweep structure: a point
    // darkened in the bright->dark phase must NOT receive a second proposal
    // in the same sweep (that biased the posterior mean by ~6% before the
    // fix). 1-d logistic, ground truth by quadrature.
    use firefly::data::LogisticData;
    use firefly::linalg::Matrix;
    use firefly::samplers::Sampler;

    let x = Matrix::from_rows(vec![
        vec![1.0],
        vec![2.0],
        vec![-0.5],
        vec![0.3],
        vec![1.5],
        vec![-1.0],
    ]);
    let t = vec![1.0, 1.0, -1.0, 1.0, -1.0, -1.0];
    let data = Arc::new(LogisticData { x: x.into(), t });
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 2.0 });

    // quadrature ground truth
    let mut num = 0.0;
    let mut den = 0.0;
    let mut sc = model.new_scratch();
    let mut g = -8.0;
    while g < 8.0 {
        let th = [g];
        let mut lp = prior.log_density(&th);
        for n in 0..6 {
            lp += model.log_lik(&th, n, &mut sc);
        }
        let w = lp.exp();
        num += g * w;
        den += w;
        g += 0.002;
    }
    let truth = num / den;

    for explicit in [false, true] {
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters));
        let mut rng = Rng::new(if explicit { 5 } else { 6 });
        let mut pp = PseudoPosterior::new(model.clone(), prior.clone(), eval, vec![0.0]);
        pp.init_z(&mut rng);
        let mut mh = RandomWalkMh::new(1.5);
        let mut theta = vec![0.0];
        let (mut sum, mut cnt) = (0.0, 0.0);
        for it in 0..400_000 {
            mh.step(&mut pp, &mut theta, &mut rng);
            if explicit {
                pp.explicit_resample(0.5, &mut rng);
            } else {
                pp.implicit_resample(0.3, &mut rng);
            }
            if it > 10_000 {
                sum += theta[0];
                cnt += 1.0;
            }
        }
        let mean = sum / cnt;
        assert!(
            (mean - truth).abs() < 0.02,
            "explicit={explicit}: flymc mean {mean} vs quadrature {truth}"
        );
    }
}

#[test]
fn augmented_joint_consistency_under_fixed_theta_gibbs() {
    // With theta *fixed*, alternating implicit z-resampling must converge to
    // the exact conditional p(z|theta) — and the pseudo-posterior value must
    // equal prior + collapsed-bounds + bright corrections recomputed fresh.
    let data = Arc::new(synth::synth_mnist(250, 10, 5));
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.0));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(8);
    let theta0: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.4).collect();
    let mut pp = PseudoPosterior::new(model.clone(), prior, eval, theta0.clone());
    pp.init_z(&mut rng);

    let mut avg_bright = 0.0;
    let sweeps = 2000;
    for _ in 0..sweeps {
        pp.implicit_resample(0.1, &mut rng);
        avg_bright += pp.n_bright() as f64 / sweeps as f64;
    }
    // expected M = sum_n (1 - B_n/L_n) at theta0
    let mut expected = 0.0;
    let mut sc = model.new_scratch();
    for n in 0..model.n() {
        let (ll, lb) = model.log_both(&theta0, n, &mut sc);
        expected += 1.0 - (lb - ll).exp();
    }
    let rel = (avg_bright - expected).abs() / expected.max(1.0);
    assert!(rel < 0.1, "avg bright {avg_bright} vs expected {expected}");

    let cached = pp.current_log_density();
    let fresh = pp.recompute_state();
    assert!((cached - fresh).abs() < 1e-8 * (1.0 + fresh.abs()));
}

#[test]
fn explicit_and_implicit_resampling_agree_in_distribution() {
    // Both z-update schemes are valid MCMC on the same conditional; at fixed
    // theta their stationary bright-count distributions must agree.
    let data = Arc::new(synth::synth_mnist(300, 8, 6));
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let mut rng = Rng::new(9);
    let theta0: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.4).collect();

    let mut run_scheme = |explicit: bool, seed: u64| -> f64 {
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters));
        let mut rng = Rng::new(seed);
        let mut pp =
            PseudoPosterior::new(model.clone(), prior.clone(), eval, theta0.clone());
        pp.init_z(&mut rng);
        let mut acc = 0.0;
        let sweeps = 3000;
        for _ in 0..sweeps {
            if explicit {
                pp.explicit_resample(0.2, &mut rng);
            } else {
                pp.implicit_resample(0.15, &mut rng);
            }
            acc += pp.n_bright() as f64 / sweeps as f64;
        }
        acc
    };

    let m_explicit = run_scheme(true, 21);
    let m_implicit = run_scheme(false, 22);
    let rel = (m_explicit - m_implicit).abs() / m_explicit.max(1.0);
    assert!(
        rel < 0.1,
        "explicit {m_explicit} vs implicit {m_implicit} bright counts"
    );
}
