//! Competitor-baseline validation, end to end through the experiment engine:
//!
//! * the exact stacks (FlyMC and full-data MH) clear the seeded
//!   `testing::posterior_check` battery against a long full-data reference
//!   chain on all three paper workloads;
//! * SGLD with a deliberately large *fixed* step (γ = 0 — no decay, so the
//!   discretization bias never vanishes) FAILS the same battery on the same
//!   posterior the exact samplers clear — the harness has real power, not
//!   just calibration;
//! * austerity MH's early-stopping decisions are deterministic under pinned
//!   seeds and its likelihood-query bill stays strictly below full MH's;
//! * the new `[approx]` config knobs are inert for the exact algorithms:
//!   byte-identical traces and an unchanged config fingerprint (the
//!   golden-stability guard for this PR — approximate samplers are strictly
//!   additive).
//!
//! Statistical comparisons project onto the leading θ components so the
//! Bonferroni battery stays small on the high-dimensional workloads; both
//! chains share the experiment seed (same prior draw for θ0), so transient
//! initialization bias largely cancels in the two-sample tests.

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::diagnostics::TraceMatrix;
use firefly::engine::run_experiment;
use firefly::testing::posterior_check::check_against_reference;

/// Keep the first `k` components of a recorded trace.
fn project(trace: &TraceMatrix, k: usize) -> TraceMatrix {
    let k = k.min(trace.dim());
    let mut out = TraceMatrix::with_capacity(k, trace.n_rows());
    for row in trace.rows() {
        out.push_row(&row[..k]);
    }
    out
}

fn workload_cfg(task: Task, algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        task,
        algorithm,
        // small-N versions of the paper workloads: every model family and
        // sampler is exercised, chains mix in test time
        n_data: Some(match task {
            Task::SoftmaxCifar => 60,
            _ => 300,
        }),
        iters: match task {
            Task::SoftmaxCifar => 1_000,
            _ => 4_000,
        },
        burnin: match task {
            Task::SoftmaxCifar => 400,
            _ => 1_500,
        },
        map_steps: 40,
        chains: 1,
        record_every: 0,
        seed: 11,
        ..Default::default()
    }
}

/// The long full-data reference chain for a workload (same seed as the
/// chains under test, so θ0 matches).
fn reference_cfg(task: Task) -> ExperimentConfig {
    let mut cfg = workload_cfg(task, Algorithm::RegularMcmc);
    cfg.iters = match task {
        Task::SoftmaxCifar => 2_400,
        _ => 10_000,
    };
    cfg
}

#[test]
fn exact_samplers_clear_posterior_check_on_all_workloads() {
    for task in [Task::LogisticMnist, Task::SoftmaxCifar, Task::RobustOpv] {
        let reference = run_experiment(&reference_cfg(task)).unwrap();
        let ref_trace = project(&reference.chains[0].theta_trace, 3);
        for algorithm in [Algorithm::MapTunedFlyMc, Algorithm::RegularMcmc] {
            let res = run_experiment(&workload_cfg(task, algorithm)).unwrap();
            let trace = project(&res.chains[0].theta_trace, 3);
            let report = check_against_reference(&trace, &ref_trace, 1e-4);
            assert!(
                report.passed(),
                "{task:?}/{algorithm:?} flagged as biased vs the reference: {:?}",
                report.failures()
            );
        }
    }
}

#[test]
fn sgld_with_large_fixed_step_fails_the_check_exact_chain_passes() {
    // Same posterior, same reference, same battery: the full-data MH chain
    // clears it, SGLD at a fixed step far above the stability scale does
    // not. This is the harness's power half — without it a check that
    // passes everything would also "pass" the exact samplers.
    let task = Task::Toy;
    let reference = run_experiment(&reference_cfg(task)).unwrap();
    let ref_trace = reference.chains[0].theta_trace.clone();

    let exact = run_experiment(&workload_cfg(task, Algorithm::RegularMcmc)).unwrap();
    let report = check_against_reference(&exact.chains[0].theta_trace, &ref_trace, 1e-4);
    assert!(report.passed(), "exact chain flagged: {:?}", report.failures());

    let mut cfg = workload_cfg(task, Algorithm::Sgld);
    cfg.minibatch = 30;
    cfg.sgld_step_a = 0.05; // far above the posterior's stability scale
    cfg.sgld_step_b = 1.0;
    cfg.sgld_step_gamma = 0.0; // fixed step: the bias never decays
    let sgld = run_experiment(&cfg).unwrap();
    let report = check_against_reference(&sgld.chains[0].theta_trace, &ref_trace, 1e-4);
    assert!(
        !report.passed(),
        "deliberately biased SGLD passed the posterior check (max |z| = {})",
        report.max_abs_z()
    );
    // and the bias is gross, not a borderline threshold crossing
    assert!(report.max_abs_z() > 2.0 * report.threshold);
}

#[test]
fn austerity_decisions_deterministic_and_cheaper_than_full_mh() {
    let mut cfg = workload_cfg(Task::LogisticMnist, Algorithm::Austerity);
    cfg.minibatch = 30;
    cfg.iters = 600;
    cfg.burnin = 200;
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    // pinned seeds: every sequential-test stopping decision, acceptance,
    // and recorded byte must repeat exactly
    assert_eq!(a.chains[0].theta_trace, b.chains[0].theta_trace);
    assert_eq!(a.chains[0].accepted, b.chains[0].accepted);
    assert_eq!(a.chains[0].queries_per_iter, b.chains[0].queries_per_iter);
    assert_eq!(a.chains[0].final_counters, b.chains[0].final_counters);

    let mut full_cfg = workload_cfg(Task::LogisticMnist, Algorithm::RegularMcmc);
    full_cfg.iters = 600;
    full_cfg.burnin = 200;
    let full = run_experiment(&full_cfg).unwrap();
    let aq = a.table_row().avg_lik_queries_per_iter;
    let fq = full.table_row().avg_lik_queries_per_iter;
    assert!(
        aq < fq,
        "austerity averaged {aq} queries/iter, not below full MH's {fq}"
    );
}

#[test]
fn approx_samplers_byte_identical_cpu_vs_parcpu() {
    // The new samplers ride the same batched likelihood path as the exact
    // stacks, so the cpu/parcpu byte-identity contract extends to them —
    // and statistical clearance on cpu transfers to parcpu verbatim.
    //
    // Why this holds per algorithm: austerity only calls `eval_lik`, whose
    // per-datum outputs are bitwise identical across backends at any batch
    // size. SGLD also calls `eval_lik_grad`, whose reduction order is a
    // function of the shard size — here the minibatch (30) fits in a single
    // shard (`ParBackend::DEFAULT_SHARD` = 64), the case par_backend's own
    // tests prove bitwise identical to the serial backend. Keep
    // minibatch ≤ DEFAULT_SHARD or this strict assertion no longer follows
    // from the backend contract (compile-time pin below).
    const MINIBATCH: usize = 30;
    const _: () = assert!(MINIBATCH <= firefly::runtime::par_backend::DEFAULT_SHARD);
    for algorithm in [Algorithm::Sgld, Algorithm::Austerity] {
        let mut c_cpu = workload_cfg(Task::LogisticMnist, algorithm);
        c_cpu.minibatch = MINIBATCH;
        c_cpu.iters = 300;
        c_cpu.burnin = 100;
        let mut c_par = c_cpu.clone();
        c_par.backend = Backend::ParCpu;
        c_par.threads = 4;
        let cpu = run_experiment(&c_cpu).unwrap();
        let par = run_experiment(&c_par).unwrap();
        assert_eq!(cpu.chains[0].theta_trace, par.chains[0].theta_trace, "{algorithm:?}");
        assert_eq!(cpu.chains[0].accepted, par.chains[0].accepted, "{algorithm:?}");
        assert_eq!(
            cpu.chains[0].queries_per_iter, par.chains[0].queries_per_iter,
            "{algorithm:?}"
        );
        assert_eq!(cpu.chains[0].final_counters, par.chains[0].final_counters, "{algorithm:?}");
    }
}

#[test]
fn approx_knobs_are_inert_for_exact_algorithms() {
    // golden-stability guard: turning every new [approx] knob must not move
    // a single byte of an exact algorithm's chain, nor its checkpoint
    // fingerprint — the approximate samplers are strictly additive
    for algorithm in [Algorithm::MapTunedFlyMc, Algorithm::RegularMcmc] {
        let mut base = workload_cfg(Task::LogisticMnist, algorithm);
        base.iters = 300;
        base.burnin = 100;
        let mut twisted = base.clone();
        twisted.minibatch = 7;
        twisted.sgld_step_a = 0.5;
        twisted.sgld_step_b = 9.0;
        twisted.sgld_step_gamma = 0.0;
        twisted.sgld_cv = true;
        twisted.austerity_eps = 0.5;
        assert_eq!(base.fingerprint(), twisted.fingerprint(), "{algorithm:?}");
        let a = run_experiment(&base).unwrap();
        let b = run_experiment(&twisted).unwrap();
        assert_eq!(a.chains[0].theta_trace, b.chains[0].theta_trace, "{algorithm:?}");
        assert_eq!(a.chains[0].logpost_joint, b.chains[0].logpost_joint, "{algorithm:?}");
        assert_eq!(a.chains[0].accepted, b.chains[0].accepted, "{algorithm:?}");
        assert_eq!(a.chains[0].final_counters, b.chains[0].final_counters, "{algorithm:?}");
    }
}
