//! Zero-allocation invariant for the **gradient** hot path: steady-state
//! FlyMC iterations on the softmax task with MALA (the paper's CIFAR-3
//! configuration, Table 1 rows 4–6) must perform **zero** heap allocations
//! on the serial CPU backend. This is the path PR 2 left open — MALA used
//! to clone θ per step and the models allocated per-datum logit/gradient
//! temporaries plus a dim-sized collapsed-gradient buffer; all of it now
//! runs through caller-owned buffers (`EvalScratch`, sampler-owned
//! gradients, the posterior's `model_scratch` — DESIGN.md §Perf).
//!
//! Measured over BOTH stores: resident `DenseStore` and an out-of-core
//! `.fbin` `BlockStore` with a cache smaller than N (misses inside the
//! measured window must not allocate — DESIGN.md §Storage).
//!
//! This binary deliberately contains a SINGLE test: the allocator counter
//! is process-global, so a sibling test allocating concurrently would
//! corrupt the measurement window. Siblings: `integration_hotpath.rs`
//! (RW-MH + logistic) and `integration_hotpath_slice.rs` (slice + robust).

use std::sync::Arc;

use firefly::data::store::BlockCacheConfig;
use firefly::data::{synth, AnyData, SoftmaxData};
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, ModelBound, Prior, SoftmaxBohning};
use firefly::runtime::CpuBackend;
use firefly::samplers::{Mala, Sampler};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn dataset(block: bool) -> SoftmaxData {
    let data = synth::synth_cifar3(240, 16, 7);
    if !block {
        return data;
    }
    let cache = BlockCacheConfig { rows_per_block: 16, cached_rows: 48 }; // << N=240
    match firefly::testing::fbin_roundtrip(&AnyData::Softmax(data), cache) {
        AnyData::Softmax(d) => d,
        other => panic!("wrong kind {}", other.kind_name()),
    }
}

#[test]
fn steady_state_mala_softmax_iterations_allocate_nothing() {
    for block in [false, true] {
        let data = Arc::new(dataset(block));
        let model: Arc<dyn ModelBound> = Arc::new(SoftmaxBohning::new(data));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 0.5 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(11);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut theta = theta0.clone();
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
        pp.init_z(&mut rng);
        let mut mala = Mala::new(0.01);

        for _ in 0..100 {
            mala.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.1, &mut rng);
        }

        let allocs_before = ALLOC.allocations();
        let queries_before = counters.lik_queries();
        let misses_before = counters.data_cache_misses();
        let mut bright_sum: usize = 0;
        for _ in 0..300 {
            mala.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.1, &mut rng);
            bright_sum += pp.n_bright();
        }
        let allocs = ALLOC.allocations() - allocs_before;
        let queries = counters.lik_queries() - queries_before;

        // the window must have exercised the gradient path for real ...
        assert!(queries > 0, "block={block}: no likelihood queries in the window");
        assert!(bright_sum > 0, "block={block}: degenerate chain, nothing ever bright");
        assert!(mala.acceptance_rate().is_finite());
        if block {
            let misses = counters.data_cache_misses() - misses_before;
            assert!(misses > 0, "block cache never missed (cache 48 < N=240)");
        }
        // ... with ZERO heap allocations (gradient half of the invariant)
        assert_eq!(
            allocs, 0,
            "block={block}: steady-state MALA+softmax FlyMC iterations performed \
             {allocs} heap allocations (zero-alloc hot-path invariant, DESIGN.md \
             §Perf/§Storage)"
        );
    }
}
