//! End-to-end parallel engine integration: the sharded `parcpu` backend must
//! be bit-identical to the serial `cpu` backend through the full chain loop
//! (θ-steps, z-resampling, query accounting), and the multi-chain replica
//! runner must be reproducible at any thread cap while reporting the
//! cross-chain diagnostics a single chain cannot produce.

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::engine::{multi_chain, run_experiment};

fn cfg(chains: usize, backend: Backend, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        backend,
        n_data: Some(400),
        iters: 60,
        burnin: 20,
        map_steps: 60,
        chains,
        threads,
        record_every: 0,
        seed: 3,
        ..Default::default()
    }
}

#[test]
fn sharded_backend_bit_identical_through_full_chains() {
    // Fixed-seed golden across backends, for both FlyMC variants: the serial
    // and sharded backends run the same scalar kernels through the same
    // u32-index hot path, so every recorded series must be byte-identical.
    for algorithm in [Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc] {
        let mut c_cpu = cfg(2, Backend::Cpu, 0);
        let mut c_par = cfg(2, Backend::ParCpu, 0);
        c_cpu.algorithm = algorithm;
        c_par.algorithm = algorithm;
        let serial = run_experiment(&c_cpu).unwrap();
        let sharded = run_experiment(&c_par).unwrap();
        assert_eq!(serial.chains.len(), sharded.chains.len());
        for (a, b) in serial.chains.iter().zip(&sharded.chains) {
            // exact equality: ll/lb are bitwise identical between backends,
            // so every accept/reject and z-flip decision is identical too
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.logpost_joint, b.logpost_joint, "{algorithm:?}");
            assert_eq!(a.bright, b.bright, "{algorithm:?}");
            assert_eq!(a.accepted, b.accepted, "{algorithm:?}");
            assert_eq!(a.theta_trace, b.theta_trace, "{algorithm:?}");
            // the paper's cost unit must not drift when the backend goes
            // parallel
            assert_eq!(a.queries_per_iter, b.queries_per_iter, "{algorithm:?}");
            assert_eq!(a.final_counters, b.final_counters, "{algorithm:?}");
            assert!(a.logpost_joint.iter().all(|l| l.is_finite()));
        }
    }
}

#[test]
fn replica_runner_reproducible_across_thread_caps() {
    let one = run_experiment(&cfg(4, Backend::Cpu, 1)).unwrap();
    let four = run_experiment(&cfg(4, Backend::Cpu, 4)).unwrap();
    for (a, b) in one.chains.iter().zip(&four.chains) {
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.logpost_joint, b.logpost_joint);
        assert_eq!(a.bright, b.bright);
        assert_eq!(a.queries_per_iter, b.queries_per_iter);
    }
}

#[test]
fn multi_chain_reports_diagnostics_with_flymc_cost() {
    let (result, summary) = multi_chain::run_multi_chain(&cfg(4, Backend::ParCpu, 0)).unwrap();
    assert_eq!(summary.replicas, 4);
    assert!(summary.split_rhat_max.is_finite(), "split-R̂ {}", summary.split_rhat_max);
    assert!(summary.split_rhat_logpost.is_finite());
    assert!(summary.pooled_ess > 0.0);
    // FlyMC's queries/iter stay far below N = 400 under the parallel engine
    assert!(
        summary.avg_queries_per_iter < 200.0,
        "queries/iter {}",
        summary.avg_queries_per_iter
    );
    let row = result.table_row();
    assert!(row.split_rhat.is_finite());
    assert!((row.split_rhat - summary.split_rhat_max).abs() < 1e-12);
}

#[test]
fn buffer_based_gradient_path_byte_identical_cpu_vs_parcpu() {
    // The scratch-arena gradient refactor must not change a single bit:
    // with the shard sized to cover the whole batch, the sharded backend
    // accumulates the per-datum pseudo-gradients in exactly the serial
    // order (one shard, reduced onto a zeroed accumulator), so a full
    // MALA+softmax FlyMC chain — gradients drive every accept/reject —
    // must be byte-identical between cpu and parcpu.
    use std::sync::Arc;

    use firefly::data::synth;
    use firefly::flymc::PseudoPosterior;
    use firefly::metrics::Counters;
    use firefly::models::{IsoGaussian, ModelBound, Prior, SoftmaxBohning};
    use firefly::runtime::{BatchEval, CpuBackend, ParBackend};
    use firefly::samplers::{Mala, Sampler, Target};
    use firefly::util::Rng;

    let n = 200;
    let data = Arc::new(synth::synth_cifar3(n, 12, 17));
    let model: Arc<dyn ModelBound> = Arc::new(SoftmaxBohning::new(data));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 0.5 });

    let run_chain = |eval: Box<dyn BatchEval>| -> (Vec<f64>, Vec<u64>, Vec<usize>) {
        let mut rng = Rng::new(23);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut theta = theta0.clone();
        let mut pp = PseudoPosterior::new(model.clone(), prior.clone(), eval, theta0);
        pp.init_z(&mut rng);
        let mut mala = Mala::new(0.01);
        let mut logpost = Vec::new();
        let mut bright = Vec::new();
        for _ in 0..120 {
            mala.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.1, &mut rng);
            logpost.push(pp.current_log_density());
            bright.push(pp.n_bright());
        }
        let bits = theta.iter().map(|t| t.to_bits()).collect();
        (logpost, bits, bright)
    };

    let cpu_counters = Counters::new();
    let (lp_cpu, th_cpu, br_cpu) =
        run_chain(Box::new(CpuBackend::new(model.clone(), cpu_counters.clone())));
    let par_counters = Counters::new();
    let (lp_par, th_par, br_par) = run_chain(Box::new(
        ParBackend::with_threads(model.clone(), par_counters.clone(), 4).with_shard(n),
    ));

    assert_eq!(th_cpu, th_par, "final theta bits differ");
    assert_eq!(br_cpu, br_par, "bright trajectories differ");
    for (i, (a, b)) in lp_cpu.iter().zip(&lp_par).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logpost bits differ at iter {i}");
    }
    // identical query accounting through the gradient path too
    assert_eq!(cpu_counters.snapshot(), par_counters.snapshot());
}

#[test]
fn regular_mcmc_full_cost_preserved_on_sharded_backend() {
    let mut c = cfg(1, Backend::ParCpu, 2);
    c.algorithm = Algorithm::RegularMcmc;
    let res = run_experiment(&c).unwrap();
    let q = res.table_row().avg_lik_queries_per_iter;
    // regular MCMC queries all N likelihoods once per MH iteration
    assert!((q - 400.0).abs() < 1e-9, "regular queries/iter {q}");
}
