//! Acceptance tests for online bound re-anchoring (DESIGN.md
//! §Bound-management):
//!
//! * exactness end-to-end: re-anchored (and q-adapted) FlyMC chains clear
//!   the seeded `testing::posterior_check` battery against a long full-data
//!   reference on all three paper workloads — the mid-run Markov restart
//!   does not bias the θ-marginal;
//! * a **no-op** re-anchor (anchor == the model's current anchor, i.e. the
//!   original MAP point) returns `false`, consumes no RNG and no likelihood
//!   queries, and leaves the downstream trace byte-identical;
//! * kill/resume **across the re-anchor boundary** is byte-identical to the
//!   uninterrupted run on both sides of the trigger (the RANC checkpoint
//!   section round-trips the Welford accumulator, the applied flag, and the
//!   frozen q-controller);
//! * cpu ↔ parcpu byte-identity holds with re-anchoring enabled (the
//!   re-anchor's batched full-N rebuild rides the same bit-exact kernel
//!   path as every other evaluation);
//! * the perf claim: post-re-anchor queries/iter drops strictly below the
//!   mis-tuned (untuned) chain's and lands at the MAP-tuned chain's level.

use std::sync::Arc;

use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::diagnostics::TraceMatrix;
use firefly::engine::experiment::build_model;
use firefly::engine::{run_experiment, run_experiment_resume, ChainResult};
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{ModelBound, Prior};
use firefly::runtime::{CpuBackend, XlaSource};
use firefly::samplers::{RandomWalkMh, Sampler};
use firefly::testing::posterior_check::check_against_reference;
use firefly::util::Rng;

/// Keep the first `k` components of a recorded trace (the Bonferroni
/// battery stays small on the high-dimensional workloads).
fn project(trace: &TraceMatrix, k: usize) -> TraceMatrix {
    let k = k.min(trace.dim());
    let mut out = TraceMatrix::with_capacity(k, trace.n_rows());
    for row in trace.rows() {
        out.push_row(&row[..k]);
    }
    out
}

fn workload_cfg(task: Task, algorithm: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        task,
        algorithm,
        n_data: Some(match task {
            Task::SoftmaxCifar => 60,
            _ => 300,
        }),
        iters: match task {
            Task::SoftmaxCifar => 1_000,
            _ => 4_000,
        },
        burnin: match task {
            Task::SoftmaxCifar => 400,
            _ => 1_500,
        },
        map_steps: 40,
        chains: 1,
        record_every: 0,
        seed: 11,
        ..Default::default()
    }
}

fn assert_chain_identical(a: &ChainResult, b: &ChainResult, label: &str) {
    assert_eq!(a.logpost_joint.len(), b.logpost_joint.len(), "{label}: lengths");
    for (i, (x, y)) in a.logpost_joint.iter().zip(&b.logpost_joint).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: logpost differs at iter {i}");
    }
    assert_eq!(a.theta_trace.n_rows(), b.theta_trace.n_rows(), "{label}: trace rows");
    for i in 0..a.theta_trace.n_rows() {
        for (x, y) in a.theta_trace.row(i).iter().zip(b.theta_trace.row(i)) {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: θ trace differs at row {i}");
        }
    }
    assert_eq!(a.bright, b.bright, "{label}: bright trajectories differ");
    assert_eq!(a.queries_per_iter, b.queries_per_iter, "{label}: query accounting differs");
    assert_eq!(a.accepted, b.accepted, "{label}: acceptance counts differ");
    assert_eq!(a.final_counters, b.final_counters, "{label}: counter totals differ");
    assert_eq!(a.stats.bright, b.stats.bright, "{label}: bright stats differ");
    assert_eq!(a.stats.bright_pre, b.stats.bright_pre, "{label}: pre-re-anchor stats differ");
    for (j, (x, y)) in a.stats.mean.iter().zip(&b.stats.mean).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: streaming mean differs at {j}");
    }
}

#[test]
fn reanchored_chains_clear_posterior_check_on_all_workloads() {
    for task in [Task::LogisticMnist, Task::SoftmaxCifar, Task::RobustOpv] {
        // long full-data reference chain, same experiment seed (same θ0)
        let mut ref_cfg = workload_cfg(task, Algorithm::RegularMcmc);
        ref_cfg.iters = match task {
            Task::SoftmaxCifar => 2_400,
            _ => 10_000,
        };
        let reference = run_experiment(&ref_cfg).unwrap();
        let ref_trace = project(&reference.chains[0].theta_trace, 3);

        let mut cfg = workload_cfg(task, Algorithm::MapTunedFlyMc);
        cfg.reanchor = true; // restart at the running posterior mean at end of burn-in
        cfg.adapt_q = true; // Robbins–Monro q-controller over the first burnin/2 iters
        let res = run_experiment(&cfg).unwrap();
        let trace = project(&res.chains[0].theta_trace, 3);
        let report = check_against_reference(&trace, &ref_trace, 1e-4);
        assert!(
            report.passed(),
            "{task:?}: re-anchored FlyMC flagged as biased vs the reference: {:?}",
            report.failures()
        );
        // the pre/post split observed both regimes
        let (min, mean, max, _) =
            res.bright_pre_stats().expect("pre-re-anchor bright stats recorded");
        assert!(min <= max && mean.is_finite(), "{task:?}: degenerate pre-re-anchor stats");
    }
}

#[test]
fn noop_reanchor_at_the_original_anchor_is_free_and_byte_identical() {
    // MAP-tuned build: the model's bound anchor IS the returned MAP point,
    // so re-anchoring there must hit the fast path — no model swap, no
    // z-restart, no RNG use, no queries — and the trace downstream of the
    // call must not move a byte.
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(250),
        map_steps: 40,
        seed: 23,
        ..Default::default()
    };
    let (source, prior, map, _) = build_model(&cfg).expect("build model");
    let anchor = map.expect("MAP-tuned build returns the anchor point");
    let model: Arc<dyn ModelBound> = source.as_model_bound();

    let run = |noop_at: Option<usize>| -> Vec<u64> {
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(77);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut pp = PseudoPosterior::new(model.clone(), prior.clone(), eval, theta0.clone());
        pp.init_z(&mut rng);
        let mut mh = RandomWalkMh::new(0.05);
        let mut theta = theta0;
        let mut bits = Vec::new();
        for it in 0..200 {
            if noop_at == Some(it) {
                let q0 = counters.lik_queries();
                assert!(
                    !pp.reanchor(&anchor, &mut rng),
                    "re-anchoring at the current anchor must be a no-op"
                );
                assert_eq!(counters.lik_queries(), q0, "no-op re-anchor consumed queries");
            }
            mh.step(&mut pp, &mut theta, &mut rng);
            pp.implicit_resample(0.05, &mut rng);
            bits.extend(theta.iter().map(|v| v.to_bits()));
        }
        bits
    };

    assert_eq!(run(None), run(Some(80)), "no-op re-anchor perturbed the trace");
}

/// Uninterrupted re-anchored run vs killed-and-resumed, for one stop point.
fn check_resume_across_boundary(stop_after: usize, label: &str) {
    let base = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(300),
        iters: 100,
        burnin: 30, // re-anchor fires at iter 30, q-adaptation freezes at 15
        map_steps: 50,
        chains: 1,
        record_every: 13,
        seed: 42,
        reanchor: true,
        adapt_q: true,
        ..Default::default()
    };
    let reference = run_experiment(&base).expect("reference run");

    let dir = std::env::temp_dir()
        .join(format!("firefly_itra_{}_{label}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut partial_cfg = base.clone();
    partial_cfg.checkpoint_dir = Some(dir.clone());
    partial_cfg.checkpoint_every = 10;
    partial_cfg.stop_after = Some(stop_after);
    run_experiment(&partial_cfg).expect("partial run");

    let mut resume_cfg = base.clone();
    resume_cfg.checkpoint_dir = Some(dir.clone());
    resume_cfg.checkpoint_every = 10;
    let resumed = run_experiment_resume(&resume_cfg, true).expect("resumed run");
    assert_chain_identical(&reference.chains[0], &resumed.chains[0], label);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn resume_is_byte_identical_across_the_reanchor_boundary() {
    // killed BEFORE the trigger: the restored Welford accumulator must feed
    // the restart inside the resumed session
    check_resume_across_boundary(20, "stop-before-boundary");
    // killed AFTER the trigger: the applied restart (swapped model, frozen
    // controller) must round-trip through the RANC section
    check_resume_across_boundary(50, "stop-after-boundary");
}

#[test]
fn reanchored_chain_byte_identical_cpu_vs_parcpu() {
    let mut c_cpu = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(300),
        iters: 100,
        burnin: 30,
        map_steps: 50,
        chains: 1,
        record_every: 0,
        seed: 42,
        reanchor: true,
        adapt_q: true,
        ..Default::default()
    };
    c_cpu.backend = Backend::Cpu;
    let mut c_par = c_cpu.clone();
    c_par.backend = Backend::ParCpu;
    c_par.threads = 4;
    let cpu = run_experiment(&c_cpu).unwrap();
    let par = run_experiment(&c_par).unwrap();
    assert_chain_identical(&cpu.chains[0], &par.chains[0], "cpu-vs-parcpu");
}

#[test]
fn reanchoring_repairs_a_mistuned_chain_to_map_tuned_cost() {
    // The perf claim behind the whole feature: an untuned (mis-anchored)
    // FlyMC chain pays a large bright set forever; re-anchoring at the
    // running posterior mean at the end of burn-in collapses its
    // steady-state cost to the MAP-tuned chain's level. The one-time full-N
    // restart pass lands inside the post-burn-in window and is amortized by
    // the comparison below.
    let mk = |algorithm: Algorithm, reanchor: bool| {
        let mut cfg = ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm,
            n_data: Some(400),
            iters: 900,
            burnin: 300,
            map_steps: 60,
            chains: 1,
            record_every: 0,
            seed: 17,
            ..Default::default()
        };
        cfg.reanchor = reanchor;
        cfg
    };
    let post_q = |cfg: &ExperimentConfig| {
        let res = run_experiment(cfg).unwrap();
        res.chains[0].avg_queries_post_burnin(cfg.burnin)
    };

    let untuned = post_q(&mk(Algorithm::UntunedFlyMc, false));
    let untuned_ra = post_q(&mk(Algorithm::UntunedFlyMc, true));
    let maptuned = post_q(&mk(Algorithm::MapTunedFlyMc, false));
    let maptuned_ra = post_q(&mk(Algorithm::MapTunedFlyMc, true));

    assert!(
        untuned_ra < untuned,
        "re-anchoring did not lower the mis-tuned chain's cost: \
         {untuned_ra} vs {untuned} queries/iter"
    );
    assert!(
        untuned_ra <= 1.1 * maptuned,
        "re-anchored mis-tuned chain ({untuned_ra} queries/iter) did not reach \
         the one-shot MAP-tuned level ({maptuned})"
    );
    assert!(
        maptuned_ra <= 1.1 * maptuned,
        "re-anchoring a well-tuned chain regressed its cost: \
         {maptuned_ra} vs {maptuned} queries/iter"
    );
}
