//! Zero-allocation hot-path invariant of the u32/scratch/flat-trace
//! refactor, enforced with a counting global allocator: steady-state FlyMC
//! iterations on the logistic task (serial CPU backend) must perform **zero**
//! heap allocations — every buffer on the θ-eval and z-resampling paths is
//! owned and pre-reserved by `PseudoPosterior`, the bright index set is
//! handed to the backend as the `BrightSet`'s own u32 prefix, and the base
//! density is one pass over a cached packed quadratic (DESIGN.md §Perf).
//!
//! This binary deliberately contains a SINGLE test: the allocator counter is
//! process-global, so a sibling test allocating concurrently would corrupt
//! the measurement window. The other paper scenarios live in their own
//! single-test binaries for the same reason — `integration_hotpath_mala.rs`
//! (MALA + softmax, the gradient path) and `integration_hotpath_slice.rs`
//! (slice + robust). The cross-backend goldens (byte-identical traces on
//! cpu vs parcpu) live in `integration_parallel.rs`.

use std::sync::Arc;

use firefly::data::synth;
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::runtime::CpuBackend;
use firefly::samplers::{RandomWalkMh, Sampler};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn build(n: usize, seed: u64) -> (PseudoPosterior, Counters, Vec<f64>, Rng) {
    let data = Arc::new(synth::synth_mnist(n, 20, seed));
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed + 100);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let theta = theta0.clone();
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
    pp.init_z(&mut rng);
    (pp, counters, theta, rng)
}

/// Measure allocations over `iters` steady-state iterations (after
/// `warmup`), with either z-resampling scheme.
fn measure(explicit: bool, warmup: usize, iters: usize) -> (u64, u64, usize) {
    let (mut pp, counters, mut theta, mut rng) = build(400, 5);
    let mut mh = RandomWalkMh::new(0.05);
    let mut z_step = |pp: &mut PseudoPosterior, rng: &mut Rng| {
        if explicit {
            pp.explicit_resample(0.1, rng);
        } else {
            pp.implicit_resample(0.1, rng);
        }
    };
    for _ in 0..warmup {
        mh.step(&mut pp, &mut theta, &mut rng);
        z_step(&mut pp, &mut rng);
    }
    let allocs_before = ALLOC.allocations();
    let queries_before = counters.lik_queries();
    for _ in 0..iters {
        mh.step(&mut pp, &mut theta, &mut rng);
        z_step(&mut pp, &mut rng);
    }
    (
        ALLOC.allocations() - allocs_before,
        counters.lik_queries() - queries_before,
        pp.n_bright(),
    )
}

#[test]
fn steady_state_flymc_iterations_allocate_nothing() {
    for explicit in [false, true] {
        let (allocs, queries, n_bright) = measure(explicit, 100, 300);
        // the window must have done real work (θ evals + z sweeps)...
        assert!(queries > 0, "explicit={explicit}: no likelihood queries");
        assert!(n_bright > 0, "explicit={explicit}: degenerate chain, nothing bright");
        // ...with ZERO heap allocations
        assert_eq!(
            allocs, 0,
            "explicit={explicit}: steady-state FlyMC iterations performed {allocs} \
             heap allocations (zero-alloc hot-path invariant, DESIGN.md §Perf)"
        );
    }
}
