//! Zero-allocation hot-path invariant of the u32/scratch/flat-trace
//! refactor, enforced with a counting global allocator: steady-state FlyMC
//! iterations on the logistic task (serial CPU backend) must perform **zero**
//! heap allocations — every buffer on the θ-eval and z-resampling paths is
//! owned and pre-reserved by `PseudoPosterior`, the bright index set is
//! handed to the backend as the `BrightSet`'s own u32 prefix, and the base
//! density is one pass over a cached packed quadratic (DESIGN.md §Perf).
//!
//! The invariant is measured over BOTH feature stores: the resident
//! `DenseStore` and an out-of-core `.fbin` `BlockStore` whose cache is
//! deliberately smaller than N, so the window takes real cache misses —
//! block fills are positioned reads into preallocated staging buffers and
//! must not allocate either (DESIGN.md §Storage).
//!
//! This binary deliberately contains a SINGLE test: the allocator counter is
//! process-global, so a sibling test allocating concurrently would corrupt
//! the measurement window. The other paper scenarios live in their own
//! single-test binaries for the same reason — `integration_hotpath_mala.rs`
//! (MALA + softmax, the gradient path) and `integration_hotpath_slice.rs`
//! (slice + robust). The cross-backend goldens (byte-identical traces on
//! cpu vs parcpu) live in `integration_parallel.rs`; dense-vs-block chain
//! byte-identity lives in `integration_store.rs`.

use std::sync::Arc;

use firefly::data::store::BlockCacheConfig;
use firefly::data::{synth, AnyData, LogisticData};
use firefly::flymc::PseudoPosterior;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::runtime::CpuBackend;
use firefly::samplers::{RandomWalkMh, Sampler};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// Synthesize the dataset; with `block`, round-trip it through a `.fbin`
/// file read back with a cache of 64 rows (N=400 → constant eviction).
fn dataset(n: usize, seed: u64, block: bool) -> LogisticData {
    let data = synth::synth_mnist(n, 20, seed);
    if !block {
        return data;
    }
    let cache = BlockCacheConfig { rows_per_block: 16, cached_rows: 64 };
    match firefly::testing::fbin_roundtrip(&AnyData::Logistic(data), cache) {
        AnyData::Logistic(d) => d,
        other => panic!("wrong kind {}", other.kind_name()),
    }
}

fn build(n: usize, seed: u64, block: bool) -> (PseudoPosterior, Counters, Vec<f64>, Rng) {
    let data = Arc::new(dataset(n, seed, block));
    assert_eq!(data.x.is_out_of_core(), block);
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed + 100);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let theta = theta0.clone();
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
    pp.init_z(&mut rng);
    (pp, counters, theta, rng)
}

/// Measure allocations over `iters` steady-state iterations (after
/// `warmup`), with either z-resampling scheme and either store.
fn measure(explicit: bool, block: bool, warmup: usize, iters: usize) -> (u64, u64, usize, u64) {
    let (mut pp, counters, mut theta, mut rng) = build(400, 5, block);
    let mut mh = RandomWalkMh::new(0.05);
    let mut z_step = |pp: &mut PseudoPosterior, rng: &mut Rng| {
        if explicit {
            pp.explicit_resample(0.1, rng);
        } else {
            pp.implicit_resample(0.1, rng);
        }
    };
    for _ in 0..warmup {
        mh.step(&mut pp, &mut theta, &mut rng);
        z_step(&mut pp, &mut rng);
    }
    let allocs_before = ALLOC.allocations();
    let queries_before = counters.lik_queries();
    let misses_before = counters.data_cache_misses();
    for _ in 0..iters {
        mh.step(&mut pp, &mut theta, &mut rng);
        z_step(&mut pp, &mut rng);
    }
    (
        ALLOC.allocations() - allocs_before,
        counters.lik_queries() - queries_before,
        pp.n_bright(),
        counters.data_cache_misses() - misses_before,
    )
}

#[test]
fn steady_state_flymc_iterations_allocate_nothing() {
    for block in [false, true] {
        for explicit in [false, true] {
            let (allocs, queries, n_bright, misses) = measure(explicit, block, 100, 300);
            // the window must have done real work (θ evals + z sweeps)...
            assert!(queries > 0, "block={block} explicit={explicit}: no likelihood queries");
            assert!(
                n_bright > 0,
                "block={block} explicit={explicit}: degenerate chain, nothing bright"
            );
            if block {
                // ...and, out of core, real cache misses (cache 64 < N=400)
                assert!(misses > 0, "explicit={explicit}: block cache never missed");
            }
            // ...with ZERO heap allocations
            assert_eq!(
                allocs, 0,
                "block={block} explicit={explicit}: steady-state FlyMC iterations \
                 performed {allocs} heap allocations (zero-alloc hot-path invariant, \
                 DESIGN.md §Perf/§Storage)"
            );
        }
    }
}
